//! Declarative construction of population-scale experiments.
//!
//! A [`ScenarioBuilder`] describes *what to run* — base experiment
//! config, population size, multi-cell [`Topology`], a
//! [`ChurnSchedule`], time-varying [`RateProcess`]es, backend name and
//! parallelism — and compiles it into a validated [`Scenario`], which
//! [`ScenarioBuilder::build`] turns into a runnable
//! [`crate::scenario::Session`]. This is the single construction path
//! for training: the legacy `Trainer` constructors and
//! `SweepRunner::trainer` are deprecated shims over it.
//!
//! Population sizing is handled declaratively: setting
//! [`ScenarioBuilder::population`] re-derives `m_train` as
//! `n * l * steps_per_epoch`, so "the same experiment at 1024 clients"
//! is one call instead of a hand-solved divisibility puzzle.
//!
//! Scenario specs can also be given as `key = value` text (the same
//! format as experiment config files): scenario keys
//! (`scenario.population`, `scenario.cells`, `scenario.churn`,
//! `scenario.link_rates`, `scenario.compute_rates`,
//! `scenario.steps_per_epoch`) are handled by the builder, everything
//! else forwards to [`ExperimentConfig::set`].

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{ExperimentConfig, Scheme};
use crate::control::ControlPolicy;
use crate::fl::trainer::SharedData;
use crate::mathx::par::Parallelism;
use crate::runtime::backend::ComputeBackend;
use crate::runtime::registry::create_backend;
use crate::scenario::session::Session;
use crate::simnet::churn::ChurnSchedule;
use crate::simnet::faults::FaultPlan;
use crate::simnet::rates::RateProcess;
use crate::simnet::topology::Topology;

/// A fully-resolved, validated scenario: everything a
/// [`crate::scenario::Session`] needs to run.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub cfg: ExperimentConfig,
    pub topology: Topology,
    pub churn: ChurnSchedule,
    /// Per-epoch modulation of client compute rates (`mu`).
    pub compute_rates: RateProcess,
    /// Per-epoch modulation of client link rates (`tau` divides by it).
    pub link_rates: RateProcess,
    pub par: Parallelism,
    /// Amortize churn parity re-encodes through
    /// [`crate::coding::encoder::ReencodeCache`] (`false` = the full
    /// re-encode oracle path, kept for the bitwise cache tests).
    pub use_reencode_cache: bool,
    /// Adaptive control-plane policy (`Off` = the static plan stays in
    /// force, bitwise the plain session).
    pub adaptive: ControlPolicy,
    /// EWMA weight of the control plane's online rate estimators.
    pub adaptive_ewma: f64,
    /// Run on the hierarchical two-tier engine
    /// ([`crate::fl::hier::HierTrainer`]): per-cell coded sub-rounds,
    /// O(active) session state, on-demand data generation. Opt-in —
    /// requires a synthetic (streamable) dataset; a trivial 1-cell
    /// hierarchical run is bitwise-equal to the flat engine.
    pub hierarchical: bool,
    /// Injected faults ([`crate::simnet::FaultPlan`]): mid-round client
    /// aborts and controller telemetry loss, drawn from a dedicated seed
    /// fork so faulted runs replay bitwise. `none` (default) never
    /// touches the fault stream.
    pub faults: FaultPlan,
    /// Emit a `"type":"metrics"` telemetry-snapshot event to the
    /// observer every this-many global steps (0 = off, the default).
    /// Host-clock derived and observe-only: the event rides the stream
    /// but never enters the deterministic [`crate::scenario::EventLog`],
    /// so enabling it cannot perturb replay comparisons.
    pub metrics_every: usize,
    /// The `key = value` pairs that reproduce this scenario through
    /// [`ScenarioBuilder::from_spec_pairs`]: the base preset
    /// (`("preset", name)`) followed by every override in application
    /// order. Recorded by the builder; empty when the scenario was built
    /// from a raw config (see [`Scenario::replayable`]).
    pub spec: Vec<(String, String)>,
    /// `false` when the construction path cannot be reproduced from
    /// `spec` alone (built from a raw [`ExperimentConfig`] or given a
    /// hand-rolled topology). Checkpointing requires a replayable
    /// scenario — the snapshot stores the spec, not the binary state of
    /// every knob.
    pub replayable: bool,
}

impl Scenario {
    /// A static full-population scenario around an existing config (the
    /// compatibility path the deprecated shims and the sweep runner use).
    pub fn static_from(cfg: &ExperimentConfig, par: Parallelism) -> Scenario {
        Scenario {
            cfg: cfg.clone(),
            topology: Topology::single_cell(),
            churn: ChurnSchedule::None,
            compute_rates: RateProcess::Static,
            link_rates: RateProcess::Static,
            par,
            use_reencode_cache: true,
            adaptive: ControlPolicy::Off,
            adaptive_ewma: DEFAULT_ADAPTIVE_EWMA,
            hierarchical: false,
            faults: FaultPlan::none(),
            metrics_every: 0,
            spec: Vec::new(),
            replayable: false,
        }
    }

    /// `true` when per-epoch dynamics never deviate from the static
    /// full-population run (topology may still be multi-cell — it is
    /// applied once at construction, not per epoch).
    pub fn is_static(&self) -> bool {
        self.churn.is_none()
            && self.compute_rates.is_static()
            && self.link_rates.is_static()
            && self.faults.is_none()
    }

    /// Validate the scenario as a whole.
    pub fn validate(&self) -> Result<()> {
        self.cfg.validate()?;
        self.topology.validate()?;
        self.churn.validate(self.cfg.n_clients)?;
        self.compute_rates.validate().context("compute_rates")?;
        self.link_rates.validate().context("link_rates")?;
        self.adaptive.validate().context("adaptive")?;
        self.faults.validate().context("faults")?;
        // The estimator weight is validated even with the policy off: a
        // spec carrying an invalid knob should fail loudly, not ride
        // along silently until someone flips the policy on.
        anyhow::ensure!(
            self.adaptive_ewma > 0.0 && self.adaptive_ewma <= 1.0,
            "scenario.adaptive.ewma {} outside (0, 1]",
            self.adaptive_ewma
        );
        if !self.adaptive.is_off() {
            anyhow::ensure!(
                self.cfg.scheme != Scheme::Uncoded,
                "adaptive control re-solves the coded load allocation; \
                 the uncoded scheme has no plan to adapt (use scenario.adaptive = off)"
            );
        }
        if self.hierarchical {
            anyhow::ensure!(
                self.adaptive.is_off(),
                "the adaptive control plane runs on the flat engine only — \
                 disable scenario.hierarchical or set scenario.adaptive = off"
            );
            anyhow::ensure!(
                self.cfg.dataset.starts_with("synth-"),
                "hierarchical sessions generate rows on demand and need a \
                 streamable synthetic dataset (synth-mnist|synth-fashion); \
                 dataset '{}' must use the flat session",
                self.cfg.dataset
            );
        }
        Ok(())
    }
}

/// Default EWMA weight of the adaptive estimators: half the mass on the
/// newest round (responsive within ~2 epochs of telemetry without
/// whipsawing on single-round noise).
const DEFAULT_ADAPTIVE_EWMA: f64 = 0.5;

/// Declarative scenario construction. All setters are chainable; call
/// [`ScenarioBuilder::build`] to compile + run-prepare.
#[derive(Debug, Clone)]
pub struct ScenarioBuilder {
    cfg: ExperimentConfig,
    population: Option<usize>,
    steps_per_epoch: Option<usize>,
    topology: Topology,
    churn: ChurnSchedule,
    compute_rates: RateProcess,
    link_rates: RateProcess,
    par: Option<Parallelism>,
    use_reencode_cache: bool,
    adaptive: ControlPolicy,
    adaptive_ewma: f64,
    hierarchical: bool,
    faults: FaultPlan,
    metrics_every: usize,
    /// Replay journal: the base preset + every recorded override, in
    /// application order (see [`Scenario::spec`]).
    spec: Vec<(String, String)>,
    replayable: bool,
}

impl ScenarioBuilder {
    /// Start from a named experiment preset (`tiny|small|medium|paper`).
    pub fn from_preset(name: &str) -> Result<ScenarioBuilder> {
        let mut b = Self::from_config(&ExperimentConfig::preset(name)?);
        b.spec.push(("preset".into(), name.into()));
        b.replayable = true;
        Ok(b)
    }

    /// Start from an existing experiment config (static scenario until
    /// dynamics are added). Scenarios built this way are **not**
    /// spec-replayable (the raw config has no recorded provenance), so
    /// sessions over them cannot be checkpointed — start from
    /// [`ScenarioBuilder::from_preset`] plus overrides when snapshots
    /// are needed.
    pub fn from_config(cfg: &ExperimentConfig) -> ScenarioBuilder {
        ScenarioBuilder {
            cfg: cfg.clone(),
            population: None,
            steps_per_epoch: None,
            topology: Topology::single_cell(),
            churn: ChurnSchedule::None,
            compute_rates: RateProcess::Static,
            link_rates: RateProcess::Static,
            par: None,
            use_reencode_cache: true,
            adaptive: ControlPolicy::Off,
            adaptive_ewma: DEFAULT_ADAPTIVE_EWMA,
            hierarchical: false,
            faults: FaultPlan::none(),
            metrics_every: 0,
            spec: Vec::new(),
            replayable: false,
        }
    }

    /// Reconstruct a builder from recorded [`Scenario::spec`] pairs (the
    /// checkpoint-restore and serve-protocol construction path). The
    /// first pair must be the `("preset", name)` base; every subsequent
    /// pair is applied through [`ScenarioBuilder::set`] in order.
    pub fn from_spec_pairs(pairs: &[(String, String)]) -> Result<ScenarioBuilder> {
        let Some(((k0, v0), rest)) = pairs.split_first() else {
            bail!("empty scenario spec (expected a leading ('preset', name) pair)");
        };
        anyhow::ensure!(
            k0 == "preset",
            "scenario spec must start with a ('preset', name) pair, got ('{k0}', '{v0}')"
        );
        let mut b = Self::from_preset(v0)?;
        for (k, v) in rest {
            b.set(k, v).with_context(|| format!("replaying spec pair '{k} = {v}'"))?;
        }
        Ok(b)
    }

    fn record(&mut self, key: &str, value: String) {
        self.spec.push((key.to_string(), value));
    }

    /// Named scenario presets — worked examples of the builder:
    ///
    /// * `static-tiny` — the tiny experiment preset, unchanged (the
    ///   bitwise-equivalence baseline);
    /// * `churn-cells` — 64 clients over 2 cells with Bernoulli churn
    ///   and diurnal link rates (a laptop-scale dynamic scenario);
    /// * `edge-1k` — 1024 clients over 2 cells with churn, diurnal
    ///   links and compute jitter (the CI population-scale smoke).
    ///   Population-scale runs soften the §A.2 geometric ladders
    ///   (`k1`/`k2` are *per-rank* decay factors, so their defaults
    ///   starve rank-1000 clients to numerically dead rates);
    /// * `edge-100k` — 100 000 clients over 32 cells on the
    ///   **hierarchical** two-tier engine (O(active) state, on-demand
    ///   data), with Bernoulli churn and diurnal link rates: the
    ///   scale-smoke scenario whose peak RSS stays sublinear in the
    ///   population.
    pub fn named(name: &str) -> Result<ScenarioBuilder> {
        match name {
            "static-tiny" => Self::from_preset("tiny"),
            "churn-cells" => {
                let mut b = Self::from_preset("tiny")?;
                b.set("net.k1", "0.99")?;
                b.set("net.k2", "0.97")?;
                Ok(b
                    .population(64)
                    .steps_per_epoch(2)
                    .cells(2)
                    .churn(ChurnSchedule::Bernoulli { p_away: 0.25, min_active: 8 })
                    .link_rates(RateProcess::Diurnal { period_epochs: 6.0, depth: 0.4 }))
            }
            "edge-1k" => {
                let mut b = Self::from_preset("tiny")?;
                b.set("net.k1", "0.997")?;
                b.set("net.k2", "0.995")?;
                b.set("train.epochs", "12")?;
                Ok(b
                    .population(1024)
                    .steps_per_epoch(1)
                    .cells(2)
                    .churn(ChurnSchedule::Bernoulli { p_away: 0.25, min_active: 32 })
                    .link_rates(RateProcess::Diurnal { period_epochs: 8.0, depth: 0.3 })
                    .compute_rates(RateProcess::Jitter { sigma: 0.1 }))
            }
            "edge-100k" => {
                let mut b = Self::from_preset("tiny")?;
                // Rank ladders flattened so rank-100k rates stay finite.
                b.set("net.k1", "0.99996")?;
                b.set("net.k2", "0.99995")?;
                b.set("train.epochs", "4")?;
                // Only the final eval fires (a full eval streams the
                // whole 100k-client batch through the generator).
                b.set("train.eval_every_steps", "1000")?;
                Ok(b
                    .population(100_000)
                    .steps_per_epoch(1)
                    .cells(32)
                    .hierarchical(true)
                    .churn(ChurnSchedule::Bernoulli { p_away: 0.25, min_active: 4096 })
                    .link_rates(RateProcess::Diurnal { period_epochs: 8.0, depth: 0.3 }))
            }
            _ => bail!(
                "unknown scenario preset '{name}' \
                 (static-tiny|churn-cells|edge-1k|edge-100k)"
            ),
        }
    }

    /// Set the population size; `m_train` is re-derived at build time as
    /// `n * l * steps_per_epoch` so the config stays consistent.
    pub fn population(mut self, n: usize) -> ScenarioBuilder {
        self.record("scenario.population", n.to_string());
        self.population = Some(n);
        self
    }

    /// Global mini-batch steps per epoch (defaults to the base config's).
    pub fn steps_per_epoch(mut self, steps: usize) -> ScenarioBuilder {
        self.record("scenario.steps_per_epoch", steps.to_string());
        self.steps_per_epoch = Some(steps);
        self
    }

    pub fn scheme(mut self, scheme: Scheme) -> ScenarioBuilder {
        self.record("scheme", scheme.name().to_string());
        self.cfg.scheme = scheme;
        self
    }

    pub fn epochs(mut self, epochs: usize) -> ScenarioBuilder {
        self.record("train.epochs", epochs.to_string());
        self.cfg.train.epochs = epochs;
        self
    }

    pub fn seed(mut self, seed: u64) -> ScenarioBuilder {
        self.record("seed", seed.to_string());
        self.cfg.seed = seed;
        self
    }

    pub fn dataset(mut self, dataset: &str) -> ScenarioBuilder {
        self.record("dataset", dataset.to_string());
        self.cfg.dataset = dataset.to_string();
        self
    }

    /// Compute backend registry name (`native` / `xla` / `auto`) —
    /// backend selection lives in the builder; `build` resolves the name
    /// through [`crate::runtime::registry`].
    pub fn backend(mut self, name: &str) -> ScenarioBuilder {
        self.record("backend", name.to_string());
        self.cfg.backend = name.to_string();
        self
    }

    /// Hand-rolled topology. Cell lists have no spec-string form, so
    /// this makes the scenario non-replayable (not checkpointable); use
    /// [`ScenarioBuilder::cells`] for the graded ladder, which is.
    pub fn topology(mut self, topo: Topology) -> ScenarioBuilder {
        self.replayable = false;
        self.topology = topo;
        self
    }

    /// Shorthand: a graded `k`-cell topology ([`Topology::graded`]).
    pub fn cells(mut self, k: usize) -> ScenarioBuilder {
        self.record("scenario.cells", k.to_string());
        self.topology = Topology::graded(k);
        self
    }

    pub fn churn(mut self, churn: ChurnSchedule) -> ScenarioBuilder {
        self.record("scenario.churn", churn.spec());
        self.churn = churn;
        self
    }

    pub fn compute_rates(mut self, p: RateProcess) -> ScenarioBuilder {
        self.record("scenario.compute_rates", p.spec());
        self.compute_rates = p;
        self
    }

    pub fn link_rates(mut self, p: RateProcess) -> ScenarioBuilder {
        self.record("scenario.link_rates", p.spec());
        self.link_rates = p;
        self
    }

    /// Explicit round parallelism (defaults to the `CODEDFEDL_THREADS` /
    /// `CODEDFEDL_SHARDS` environment knobs). Bitwise-neutral.
    pub fn parallelism(mut self, par: Parallelism) -> ScenarioBuilder {
        self.par = Some(par);
        self
    }

    /// Disable the [`crate::coding::encoder::ReencodeCache`] on the
    /// churn parity path (test oracle: the uncached full re-encode).
    pub fn reencode_cache(mut self, on: bool) -> ScenarioBuilder {
        self.record("scenario.reencode_cache", on.to_string());
        self.use_reencode_cache = on;
        self
    }

    /// Adaptive control-plane policy ([`crate::control`]): `Off`
    /// (default) keeps the construction plan in force for the whole run
    /// — bitwise the plain session; any other policy closes the loop
    /// from streaming round telemetry to online load re-allocation.
    /// Requires a coded scheme (the uncoded baseline has no plan).
    pub fn adaptive(mut self, policy: ControlPolicy) -> ScenarioBuilder {
        self.record("scenario.adaptive", policy.spec());
        self.adaptive = policy;
        self
    }

    /// EWMA weight of the adaptive rate estimators, in (0, 1] (spec key
    /// `scenario.adaptive.ewma`; default 0.5).
    pub fn adaptive_ewma(mut self, w: f64) -> ScenarioBuilder {
        // `{}` on f64 prints the shortest decimal that parses back to
        // the same bits, so the recorded pair replays exactly.
        self.record("scenario.adaptive.ewma", format!("{w}"));
        self.adaptive_ewma = w;
        self
    }

    /// Run on the hierarchical two-tier engine (spec key
    /// `scenario.hierarchical`): per-cell coded sub-rounds, O(active)
    /// state, on-demand data. Requires a synthetic dataset; a 1-cell
    /// hierarchical run is bitwise-equal to the flat session.
    pub fn hierarchical(mut self, on: bool) -> ScenarioBuilder {
        self.record("scenario.hierarchical", on.to_string());
        self.hierarchical = on;
        self
    }

    /// Injected-fault plan (spec key `scenario.faults`, e.g.
    /// `abort:0.1+telemetry:0.2+seed:3`): mid-round client aborts and
    /// controller telemetry loss, drawn from a dedicated fault seed fork
    /// so faulted runs replay bitwise and faults-off runs are untouched.
    pub fn faults(mut self, plan: FaultPlan) -> ScenarioBuilder {
        self.record("scenario.faults", plan.spec());
        self.faults = plan;
        self
    }

    /// Telemetry-snapshot event cadence (spec key
    /// `scenario.metrics_every`; 0 = off, the default): every this-many
    /// global steps the session emits the current
    /// [`crate::telemetry::snapshot`] as a `"type":"metrics"` stream
    /// event. Observe-only — never perturbs the deterministic streams.
    pub fn metrics_every(mut self, every: usize) -> ScenarioBuilder {
        self.record("scenario.metrics_every", every.to_string());
        self.metrics_every = every;
        self
    }

    /// Apply one `key = value` override. Scenario keys are prefixed
    /// `scenario.`; everything else forwards to
    /// [`ExperimentConfig::set`]. Applied pairs are recorded in the
    /// replay journal ([`Scenario::spec`]).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let v = value.trim();
        match key.trim() {
            "scenario.population" => self.population = Some(v.parse()?),
            "scenario.steps_per_epoch" => self.steps_per_epoch = Some(v.parse()?),
            "scenario.cells" => self.topology = Topology::parse(v)?,
            "scenario.churn" => self.churn = ChurnSchedule::parse(v)?,
            "scenario.link_rates" => self.link_rates = RateProcess::parse(v)?,
            "scenario.compute_rates" => self.compute_rates = RateProcess::parse(v)?,
            "scenario.reencode_cache" => self.use_reencode_cache = v.parse()?,
            "scenario.adaptive" => self.adaptive = ControlPolicy::parse(v)?,
            "scenario.adaptive.ewma" => self.adaptive_ewma = v.parse()?,
            "scenario.hierarchical" => self.hierarchical = v.parse()?,
            "scenario.faults" => self.faults = FaultPlan::parse(v)?,
            "scenario.metrics_every" => self.metrics_every = v.parse()?,
            other => self.cfg.set(other, value)?,
        }
        self.record(key.trim(), v.to_string());
        Ok(())
    }

    /// Apply a `key = value` scenario spec file (same syntax as config
    /// files; `scenario.*` keys plus config overrides).
    pub fn apply_file(&mut self, path: &str) -> Result<()> {
        crate::config::parse_kv_file(path, &mut |k, v| self.set(k, v))
    }

    /// Compile into a validated [`Scenario`] (resolving the population
    /// and step-count declarations into a consistent config).
    pub fn compile(self) -> Result<Scenario> {
        let mut cfg = self.cfg;
        let steps = match self.steps_per_epoch {
            Some(s) => {
                anyhow::ensure!(s >= 1, "steps_per_epoch must be >= 1");
                s
            }
            None => cfg.steps_per_epoch().max(1),
        };
        if self.population.is_some() || self.steps_per_epoch.is_some() {
            if let Some(n) = self.population {
                anyhow::ensure!(n >= 1, "population must be >= 1");
                cfg.n_clients = n;
            }
            cfg.m_train = cfg.n_clients * cfg.profile.l * steps;
        }
        let scenario = Scenario {
            cfg,
            topology: self.topology,
            churn: self.churn,
            compute_rates: self.compute_rates,
            link_rates: self.link_rates,
            par: self.par.unwrap_or_else(Parallelism::from_env),
            use_reencode_cache: self.use_reencode_cache,
            adaptive: self.adaptive,
            adaptive_ewma: self.adaptive_ewma,
            hierarchical: self.hierarchical,
            faults: self.faults,
            metrics_every: self.metrics_every,
            spec: self.spec,
            replayable: self.replayable,
        };
        scenario.validate()?;
        Ok(scenario)
    }

    /// Compile and build a runnable [`Session`]. The backend is resolved
    /// by name through the registry. Flat scenarios build the dataset +
    /// RFF embedding here; hierarchical scenarios build **no** shared
    /// dense state at all (their rows are generated on demand).
    pub fn build(self) -> Result<Session> {
        let scenario = self.compile()?;
        let backend = create_backend(&scenario.cfg.backend, &scenario.cfg)?;
        if scenario.hierarchical {
            return Session::new_hier(scenario, backend);
        }
        let shared = Arc::new(SharedData::build(&scenario.cfg, backend.as_ref())?);
        Session::new(scenario, backend, shared)
    }

    /// [`ScenarioBuilder::build`] with an injected backend (tests).
    pub fn build_with_backend(self, backend: Box<dyn ComputeBackend>) -> Result<Session> {
        let scenario = self.compile()?;
        if scenario.hierarchical {
            return Session::new_hier(scenario, backend);
        }
        let shared = Arc::new(SharedData::build(&scenario.cfg, backend.as_ref())?);
        Session::new(scenario, backend, shared)
    }

    /// [`ScenarioBuilder::build`] on pre-built [`SharedData`] (the sweep
    /// fast path: variants share one embedding). Flat scenarios only —
    /// a hierarchical session holds no shared dense state to reuse.
    pub fn build_with_shared(
        self,
        backend: Box<dyn ComputeBackend>,
        shared: Arc<SharedData>,
    ) -> Result<Session> {
        let scenario = self.compile()?;
        anyhow::ensure!(
            !scenario.hierarchical,
            "hierarchical scenarios build no SharedData — use build/build_with_backend"
        );
        Session::new(scenario, backend, shared)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn population_rescales_m_train() {
        let s = ScenarioBuilder::from_preset("tiny")
            .unwrap()
            .population(64)
            .steps_per_epoch(2)
            .compile()
            .unwrap();
        assert_eq!(s.cfg.n_clients, 64);
        assert_eq!(s.cfg.m_train, 64 * s.cfg.profile.l * 2);
        assert_eq!(s.cfg.steps_per_epoch(), 2);
        s.cfg.validate().unwrap();
    }

    #[test]
    fn default_is_a_static_single_cell_scenario() {
        let base = ExperimentConfig::preset("tiny").unwrap();
        let s = ScenarioBuilder::from_config(&base).compile().unwrap();
        assert!(s.is_static());
        assert!(s.topology.is_trivial());
        // No population/steps declaration: the config is untouched.
        assert_eq!(s.cfg.m_train, base.m_train);
        assert_eq!(s.cfg.n_clients, base.n_clients);
    }

    #[test]
    fn dynamics_make_it_non_static() {
        let s = ScenarioBuilder::from_preset("tiny")
            .unwrap()
            .churn(ChurnSchedule::Bernoulli { p_away: 0.2, min_active: 1 })
            .compile()
            .unwrap();
        assert!(!s.is_static());
        let s2 = ScenarioBuilder::from_preset("tiny")
            .unwrap()
            .link_rates(RateProcess::Jitter { sigma: 0.1 })
            .compile()
            .unwrap();
        assert!(!s2.is_static());
    }

    #[test]
    fn spec_keys_parse_and_forward() {
        let mut b = ScenarioBuilder::from_preset("tiny").unwrap();
        b.set("scenario.population", "32").unwrap();
        b.set("scenario.cells", "2").unwrap();
        b.set("scenario.churn", "bernoulli:0.3:4").unwrap();
        b.set("scenario.link_rates", "diurnal:8:0.4").unwrap();
        b.set("scenario.compute_rates", "jitter:0.2").unwrap();
        b.set("scenario.steps_per_epoch", "1").unwrap();
        b.set("train.epochs", "3").unwrap(); // forwarded to the config
        let s = b.compile().unwrap();
        assert_eq!(s.cfg.n_clients, 32);
        assert_eq!(s.topology.n_cells(), 2);
        assert_eq!(s.churn, ChurnSchedule::Bernoulli { p_away: 0.3, min_active: 4 });
        assert_eq!(s.cfg.train.epochs, 3);
        assert!(!s.is_static());
    }

    #[test]
    fn adaptive_spec_keys_parse_and_validate() {
        let mut b = ScenarioBuilder::from_preset("tiny").unwrap();
        b.set("scenario.adaptive", "drift:0.08").unwrap();
        b.set("scenario.adaptive.ewma", "0.3").unwrap();
        let s = b.compile().unwrap();
        assert_eq!(s.adaptive, ControlPolicy::Drift { threshold: 0.08 });
        assert_eq!(s.adaptive_ewma, 0.3);
        // Default stays off, and off is valid on any scheme.
        let d = ScenarioBuilder::from_preset("tiny").unwrap().compile().unwrap();
        assert!(d.adaptive.is_off());
        // Adaptive control needs a coded plan to adapt.
        let bad = ScenarioBuilder::from_preset("tiny")
            .unwrap()
            .scheme(Scheme::Uncoded)
            .adaptive(ControlPolicy::Drift { threshold: 0.1 });
        assert!(bad.compile().is_err());
        // Bad estimator weight is rejected at compile time — even with
        // the policy off (no invalid knob rides along silently).
        let bad_ewma = ScenarioBuilder::from_preset("tiny")
            .unwrap()
            .adaptive(ControlPolicy::Periodic { every_epochs: 2 })
            .adaptive_ewma(1.5);
        assert!(bad_ewma.compile().is_err());
        let bad_off = ScenarioBuilder::from_preset("tiny").unwrap().adaptive_ewma(0.0);
        assert!(bad_off.compile().is_err());
    }

    #[test]
    fn metrics_every_spec_key_parses_and_defaults_off() {
        let d = ScenarioBuilder::from_preset("tiny").unwrap().compile().unwrap();
        assert_eq!(d.metrics_every, 0, "metrics events are opt-in");
        let mut b = ScenarioBuilder::from_preset("tiny").unwrap();
        b.set("scenario.metrics_every", "5").unwrap();
        let s = b.compile().unwrap();
        assert_eq!(s.metrics_every, 5);
        // Observe-only: the knob never flips a scenario to dynamic.
        assert!(s.is_static());
        // And it rides the replay journal like every other knob.
        let s2 = ScenarioBuilder::from_spec_pairs(&s.spec).unwrap().compile().unwrap();
        assert_eq!(s2.metrics_every, 5);
    }

    #[test]
    fn fault_spec_key_parses_and_gates_staticness() {
        let mut b = ScenarioBuilder::from_preset("tiny").unwrap();
        b.set("scenario.faults", "abort:0.1+telemetry:0.2+seed:3").unwrap();
        let s = b.compile().unwrap();
        assert_eq!(
            s.faults,
            FaultPlan { abort_p: 0.1, telemetry_loss_p: 0.2, seed: 3 }
        );
        // An otherwise-static scenario with faults is not static: the
        // session must take the RoundCtx path to thread the abort sets.
        assert!(!s.is_static());
        // The default plan keeps scenarios static, and bad plans are
        // rejected at compile time.
        let d = ScenarioBuilder::from_preset("tiny").unwrap().compile().unwrap();
        assert!(d.faults.is_none());
        assert!(d.is_static());
        let bad = ScenarioBuilder::from_preset("tiny")
            .unwrap()
            .faults(FaultPlan { abort_p: 1.0, telemetry_loss_p: 0.0, seed: 0 });
        assert!(bad.compile().is_err());
    }

    #[test]
    fn bad_specs_are_rejected() {
        let mut b = ScenarioBuilder::from_preset("tiny").unwrap();
        assert!(b.set("scenario.churn", "sometimes").is_err());
        assert!(b.set("scenario.cells", "0").is_err());
        assert!(b.set("scenario.adaptive", "sometimes").is_err());
        assert!(b.set("scenario.faults", "sometimes").is_err());
        assert!(b.set("nope.key", "1").is_err());
        // Churn floor above the population fails at compile time.
        let bad = ScenarioBuilder::from_preset("tiny")
            .unwrap()
            .population(8)
            .churn(ChurnSchedule::Bernoulli { p_away: 0.5, min_active: 9 });
        assert!(bad.compile().is_err());
    }

    #[test]
    fn named_presets_compile() {
        for name in ["static-tiny", "churn-cells", "edge-1k", "edge-100k"] {
            let s = ScenarioBuilder::named(name).unwrap().compile().unwrap();
            s.validate().unwrap();
            if name == "edge-1k" {
                assert_eq!(s.cfg.n_clients, 1024);
                assert_eq!(s.topology.n_cells(), 2);
                assert!(!s.is_static());
            }
            if name == "edge-100k" {
                assert_eq!(s.cfg.n_clients, 100_000);
                assert_eq!(s.cfg.m_train, 100_000 * s.cfg.profile.l);
                assert_eq!(s.topology.n_cells(), 32);
                assert!(s.hierarchical, "edge-100k runs the two-tier engine");
                assert!(!s.is_static());
                assert_eq!(
                    s.churn,
                    ChurnSchedule::Bernoulli { p_away: 0.25, min_active: 4096 }
                );
            }
        }
        assert!(ScenarioBuilder::named("mystery").is_err());
    }

    #[test]
    fn hierarchical_flag_parses_and_validates() {
        let mut b = ScenarioBuilder::from_preset("tiny").unwrap();
        b.set("scenario.hierarchical", "true").unwrap();
        let s = b.compile().unwrap();
        assert!(s.hierarchical);
        // Hierarchical + adaptive control is rejected (flat engine only).
        let bad = ScenarioBuilder::from_preset("tiny")
            .unwrap()
            .hierarchical(true)
            .adaptive(ControlPolicy::Periodic { every_epochs: 2 });
        assert!(bad.compile().is_err());
        // Hierarchical needs a streamable synthetic dataset.
        let bad = ScenarioBuilder::from_preset("tiny")
            .unwrap()
            .hierarchical(true)
            .dataset("mnist");
        assert!(bad.compile().is_err());
    }

    #[test]
    fn recorded_spec_pairs_replay_the_scenario() {
        // Chainable setters, `set` overrides and named presets all record
        // into the replay journal; rebuilding from the journal yields an
        // identical scenario (the checkpoint-restore construction path).
        let mut b = ScenarioBuilder::from_preset("tiny")
            .unwrap()
            .scheme(Scheme::Coded)
            .epochs(3)
            .population(16)
            .steps_per_epoch(2)
            .cells(2)
            .churn(ChurnSchedule::Bernoulli { p_away: 0.3, min_active: 4 })
            .link_rates(RateProcess::Diurnal { period_epochs: 4.0, depth: 0.3 })
            .adaptive(ControlPolicy::Drift { threshold: 0.07 })
            .adaptive_ewma(0.4)
            .faults(FaultPlan { abort_p: 0.05, telemetry_loss_p: 0.0, seed: 2 });
        b.set("backend", "native").unwrap();
        let s = b.compile().unwrap();
        assert!(s.replayable);
        assert_eq!(s.spec[0], ("preset".to_string(), "tiny".to_string()));
        let s2 = ScenarioBuilder::from_spec_pairs(&s.spec).unwrap().compile().unwrap();
        assert_eq!(s2.spec, s.spec);
        assert_eq!(s2.cfg.n_clients, s.cfg.n_clients);
        assert_eq!(s2.cfg.m_train, s.cfg.m_train);
        assert_eq!(s2.cfg.seed, s.cfg.seed);
        assert_eq!(s2.cfg.scheme, s.cfg.scheme);
        assert_eq!(s2.cfg.backend, s.cfg.backend);
        assert_eq!(s2.churn, s.churn);
        assert_eq!(s2.link_rates, s.link_rates);
        assert_eq!(s2.adaptive, s.adaptive);
        assert_eq!(s2.adaptive_ewma, s.adaptive_ewma);
        assert_eq!(s2.faults, s.faults);
        assert_eq!(s2.topology.n_cells(), s.topology.n_cells());
        assert_eq!(s2.hierarchical, s.hierarchical);

        // Named presets replay too (their construction is recorded).
        let e = ScenarioBuilder::named("edge-1k").unwrap().compile().unwrap();
        assert!(e.replayable);
        let e2 = ScenarioBuilder::from_spec_pairs(&e.spec).unwrap().compile().unwrap();
        assert_eq!(e2.cfg.n_clients, e.cfg.n_clients);
        assert_eq!(e2.churn, e.churn);

        // Raw-config and hand-rolled-topology paths are not replayable.
        let base = ExperimentConfig::preset("tiny").unwrap();
        assert!(!ScenarioBuilder::from_config(&base).compile().unwrap().replayable);
        let custom = ScenarioBuilder::from_preset("tiny")
            .unwrap()
            .topology(Topology::graded(2))
            .compile()
            .unwrap();
        assert!(!custom.replayable);
        assert!(ScenarioBuilder::from_spec_pairs(&[]).is_err());
    }

    #[test]
    fn spec_file_roundtrip() {
        let dir = std::env::temp_dir().join("codedfedl_scenario_spec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("edge.scenario");
        std::fs::write(
            &path,
            "# population-scale spec\nscenario.population = 16\nscenario.churn = block:0.25:2\ntrain.epochs = 2\n",
        )
        .unwrap();
        let mut b = ScenarioBuilder::from_preset("tiny").unwrap();
        b.apply_file(path.to_str().unwrap()).unwrap();
        let s = b.compile().unwrap();
        assert_eq!(s.cfg.n_clients, 16);
        assert_eq!(s.cfg.train.epochs, 2);
        assert_eq!(
            s.churn,
            ChurnSchedule::RotatingBlock { fraction_away: 0.25, period_epochs: 2 }
        );
    }
}
