"""L2 model-level tests: update math, predict, and a miniature end-to-end
gradient-descent convergence check built only from the AOT entry points."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def test_sgd_update_math():
    rng = np.random.default_rng(0)
    beta = jnp.asarray(rng.standard_normal((8, 3)).astype(np.float32))
    grad = jnp.asarray(rng.standard_normal((8, 3)).astype(np.float32))
    got = model.sgd_update(beta, grad, jnp.float32(0.1), jnp.float32(0.01))
    want = beta - 0.1 * (grad + 0.01 * beta)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_sgd_update_zero_lr_is_identity():
    beta = jnp.ones((4, 2), jnp.float32)
    got = model.sgd_update(beta, 5.0 * beta, jnp.float32(0.0), jnp.float32(0.5))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(beta))


def test_predict_matches_matmul():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32))
    beta = jnp.asarray(rng.standard_normal((8, 5)).astype(np.float32))
    np.testing.assert_allclose(model.predict_logits(x, beta), x @ beta,
                               rtol=1e-5)


def test_gradient_entry_point_delegates_to_kernel():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((24, 8)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((24, 3)).astype(np.float32))
    beta = jnp.asarray(rng.standard_normal((8, 3)).astype(np.float32))
    mask = jnp.ones((24, 1), jnp.float32)
    np.testing.assert_allclose(model.gradient(x, y, beta, mask),
                               ref.gradient_ref(x, y, beta, mask),
                               rtol=1e-4, atol=1e-4)


def test_end_to_end_gd_converges():
    # Full-batch GD on a tiny linear system using only AOT entry points:
    # the loss must drop by orders of magnitude — validates sign/scale
    # conventions across gradient+update exactly as rust will chain them.
    rng = np.random.default_rng(3)
    m, q, c = 64, 8, 3
    x = jnp.asarray(rng.standard_normal((m, q)).astype(np.float32) / np.sqrt(q))
    true_beta = jnp.asarray(rng.standard_normal((q, c)).astype(np.float32))
    y = x @ true_beta
    mask = jnp.ones((m, 1), jnp.float32)
    beta = jnp.zeros((q, c), jnp.float32)
    lr, lam = jnp.float32(0.9), jnp.float32(0.0)

    def loss(b):
        return float(jnp.mean((x @ b - y) ** 2))

    l0 = loss(beta)
    for _ in range(300):
        g = model.gradient(x, y, beta, mask) / m
        beta = model.sgd_update(beta, g, lr, lam)
    l1 = loss(beta)
    assert l1 < 1e-4 * max(l0, 1e-9), f"GD failed to converge: {l0} -> {l1}"


def test_rff_plus_linear_separates_nonlinear_data():
    # Two classes on concentric circles: raw-linear regression cannot
    # separate them, RFF + linear can. This is the paper's Section 3.1
    # claim in miniature.
    rng = np.random.default_rng(4)
    m_per, d, q, sigma = 60, 2, 256, 0.7
    r_in = 1.0 + 0.05 * rng.standard_normal(m_per)
    r_out = 2.0 + 0.05 * rng.standard_normal(m_per)
    th = rng.uniform(0, 2 * np.pi, 2 * m_per)
    r = np.concatenate([r_in, r_out])
    x = np.stack([r * np.cos(th), r * np.sin(th)], axis=1).astype(np.float32)
    ylab = np.concatenate([np.zeros(m_per), np.ones(m_per)]).astype(int)
    y = np.eye(2, dtype=np.float32)[ylab]

    omega = (rng.standard_normal((d, q)) / sigma).astype(np.float32)
    delta = rng.uniform(0, 2 * np.pi, (1, q)).astype(np.float32)
    xh = model.rff_embed(jnp.asarray(x), jnp.asarray(omega), jnp.asarray(delta))

    def train(feats):
        feats = jnp.asarray(feats)
        labels = jnp.asarray(y)
        mask = jnp.ones((feats.shape[0], 1), jnp.float32)
        beta = jnp.zeros((feats.shape[1], 2), jnp.float32)
        for _ in range(400):
            g = model.gradient(feats, labels, beta, mask) / feats.shape[0]
            beta = model.sgd_update(beta, g, jnp.float32(1.5), jnp.float32(1e-6))
        pred = np.asarray(model.predict_logits(feats, beta)).argmax(1)
        return (pred == ylab).mean()

    acc_linear = train(x)
    acc_rff = train(xh)
    assert acc_rff > 0.95, f"RFF accuracy too low: {acc_rff}"
    assert acc_rff > acc_linear + 0.2, (
        f"RFF ({acc_rff}) should clearly beat raw linear ({acc_linear})")
