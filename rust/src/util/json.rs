//! Minimal JSON value type with a recursive-descent parser and emitter.
//!
//! Used to read `artifacts/manifest.json` (the ABI contract written by
//! `python/compile/aot.py`) and to emit experiment result files. Supports
//! the full JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the key name (manifest reads).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Array of integers helper (shape lists in the manifest).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Convenience constructors.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string().context("object key")?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end])?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = text.parse().with_context(|| format!("bad number '{text}'"))?;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"dims":{"c":10,"d":784},"list":[1,2.5,true,null,"s"]}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn usize_vec() {
        let j = Json::parse("[4, 5, 6]").unwrap();
        assert_eq!(j.as_usize_vec().unwrap(), vec![4, 5, 6]);
        assert!(Json::parse("[1.5]").unwrap().as_usize_vec().is_err());
    }

    #[test]
    fn escapes_on_emit() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_string() {
        let j = Json::parse(r#""héllo ☃""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ☃");
    }
}
