//! Integration: the paper's central correctness claim (eqs. 11-13) —
//! coded gradient + expected uncoded return = full mini-batch gradient:
//! `E[g_C + g_U] = m * g_hat`, with the expectation over BOTH the
//! generator matrices G_j and the straggler pattern.
//!
//! Built from the same components the trainer uses (weights, encoder,
//! gradient oracle), at a scale where a few hundred Monte-Carlo trials
//! tighten the estimate well below the asserted tolerance.

use codedfedl::coding::encoder::{encode_client_slice, CompositeParity};
use codedfedl::coding::weights::build_weights;
use codedfedl::mathx::linalg::{gradient_ref, Matrix};
use codedfedl::mathx::rng::Rng;
use codedfedl::runtime::backend::NativeBackend;

#[test]
fn coded_plus_uncoded_equals_full_gradient_in_expectation() {
    let mut rng = Rng::new(42);
    let (n, l, q, c, u) = (4usize, 8usize, 6usize, 3usize, 64usize);
    let m_batch = n * l;

    // Fixed client slices, model, per-client return probabilities.
    let xs: Vec<Matrix> = (0..n).map(|_| Matrix::randn(l, q, 0.0, 1.0, &mut rng)).collect();
    let ys: Vec<Matrix> = (0..n).map(|_| Matrix::randn(l, c, 0.0, 1.0, &mut rng)).collect();
    let beta = Matrix::randn(q, c, 0.0, 1.0, &mut rng);
    let p_return = [0.9, 0.6, 0.3, 0.8];
    // Client j processes a fixed subset of its slice (the allocator's l*).
    let loads = [6usize, 5, 3, 8];
    let processed: Vec<Vec<usize>> = (0..n).map(|j| (0..loads[j]).collect()).collect();

    // Ground truth: full-batch gradient sum over ALL n*l rows.
    let full: Matrix = {
        let mut acc = Matrix::zeros(q, c);
        for j in 0..n {
            acc.axpy_inplace(1.0, &gradient_ref(&xs[j], &ys[j], &beta, &vec![1.0; l]).unwrap());
        }
        acc
    };

    // Monte-Carlo over (G, straggler pattern).
    let nb = NativeBackend;
    let trials = 600;
    let mut acc = Matrix::zeros(q, c);
    for _ in 0..trials {
        // Encode with fresh private generators (as before each batch).
        let mut comp = CompositeParity::zeros(u, u, q, c);
        for j in 0..n {
            let w = build_weights(l, &processed[j], 1.0 - p_return[j]);
            let (xc, yc) =
                encode_client_slice(&nb, &xs[j], &ys[j], &w, u, u, &mut rng).unwrap();
            comp.add(&xc, &yc);
        }
        let mut g = gradient_ref(&comp.x, &comp.y, &beta, &comp.mask()).unwrap();
        // Sample arrivals and add uncoded contributions.
        for j in 0..n {
            if rng.next_f64() < p_return[j] {
                let mut mask = vec![0.0f32; l];
                for &k in &processed[j] {
                    mask[k] = 1.0;
                }
                g.axpy_inplace(1.0, &gradient_ref(&xs[j], &ys[j], &beta, &mask).unwrap());
            }
        }
        acc.axpy_inplace(1.0 / trials as f32, &g);
    }

    let scale = full.data().iter().fold(0.0f32, |a, &b| a.max(b.abs()));
    let rel = acc.max_abs_diff(&full) / scale;
    assert!(
        rel < 0.15,
        "E[g_C + g_U] deviates from full gradient by {:.1}% (m_batch {m_batch})",
        100.0 * rel
    );
}

#[test]
fn dropping_the_weights_breaks_unbiasedness() {
    // Ablation of the §3.4 weight matrix: with W_j = I the estimator is
    // clearly biased whenever clients straggle — the weights are load-
    // bearing, not decorative.
    let mut rng = Rng::new(43);
    let (l, q, c, u) = (10usize, 5usize, 2usize, 64usize);
    let x = Matrix::randn(l, q, 0.0, 1.0, &mut rng);
    let y = Matrix::randn(l, c, 0.0, 1.0, &mut rng);
    let beta = Matrix::randn(q, c, 0.0, 1.0, &mut rng);
    let p_return = 0.5;
    let processed: Vec<usize> = (0..l).collect();

    let full = gradient_ref(&x, &y, &beta, &vec![1.0; l]).unwrap();
    let nb = NativeBackend;
    let trials = 800;
    let mut acc = Matrix::zeros(q, c);
    for _ in 0..trials {
        let w = vec![1.0f32; l]; // WRONG: identity weights
        let (xc, yc) = encode_client_slice(&nb, &x, &y, &w, u, u, &mut rng).unwrap();
        let mut g = gradient_ref(&xc, &yc, &beta, &vec![1.0; u]).unwrap();
        if rng.next_f64() < p_return {
            let mut mask = vec![0.0f32; l];
            for &k in &processed {
                mask[k] = 1.0;
            }
            g.axpy_inplace(1.0, &gradient_ref(&x, &y, &beta, &mask).unwrap());
        }
        acc.axpy_inplace(1.0 / trials as f32, &g);
    }
    // E[g] = (1 + p) * full, i.e. 50% too large — far outside noise.
    let scale = full.data().iter().fold(0.0f32, |a, &b| a.max(b.abs()));
    let rel = acc.max_abs_diff(&full) / scale;
    assert!(
        rel > 0.25,
        "identity weights should visibly bias the estimate (got {:.1}%)",
        100.0 * rel
    );
}
