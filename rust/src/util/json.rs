//! Minimal JSON value type with a recursive-descent parser and emitter.
//!
//! Used to read `artifacts/manifest.json` (the ABI contract written by
//! `python/compile/aot.py`) and to emit experiment result files. Supports
//! the full JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the key name (manifest reads).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Array of integers helper (shape lists in the manifest).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Convenience constructors.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

// ---- exact bit-pattern encoding --------------------------------------
//
// `Json::Num` is an `f64`, so `u64` counters and exact `f32`/`f64` values
// (rng state words, model weights, plan deadlines) cannot round-trip
// through decimal text. Checkpoint formats instead store such values as
// fixed-width lowercase-hex strings of their bit patterns; these helpers
// are the single encode/decode point so every format agrees byte-for-byte.

/// `u64` → fixed-width (16-char) lowercase hex.
pub fn u64_to_hex(v: u64) -> String {
    format!("{v:016x}")
}

/// Inverse of [`u64_to_hex`].
pub fn hex_to_u64(s: &str) -> Result<u64> {
    u64::from_str_radix(s.trim(), 16).with_context(|| format!("bad u64 hex '{s}'"))
}

/// `f64` → the hex of its IEEE-754 bit pattern (exact round-trip).
pub fn f64_to_hex(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

/// Inverse of [`f64_to_hex`].
pub fn hex_to_f64(s: &str) -> Result<f64> {
    Ok(f64::from_bits(hex_to_u64(s)?))
}

/// `f32` → the hex of its IEEE-754 bit pattern (exact round-trip).
pub fn f32_to_hex(v: f32) -> String {
    format!("{:08x}", v.to_bits())
}

/// Inverse of [`f32_to_hex`].
pub fn hex_to_f32(s: &str) -> Result<f32> {
    let b = u32::from_str_radix(s.trim(), 16).with_context(|| format!("bad f32 hex '{s}'"))?;
    Ok(f32::from_bits(b))
}

/// A `Json` array of [`f64_to_hex`] strings.
pub fn arr_f64_hex(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Str(f64_to_hex(x))).collect())
}

/// Inverse of [`arr_f64_hex`].
pub fn f64_vec_from_hex(j: &Json) -> Result<Vec<f64>> {
    j.as_arr()?.iter().map(|v| hex_to_f64(v.as_str()?)).collect()
}

/// A `Json` array of [`f32_to_hex`] strings.
pub fn arr_f32_hex(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Str(f32_to_hex(x))).collect())
}

/// Inverse of [`arr_f32_hex`].
pub fn f32_vec_from_hex(j: &Json) -> Result<Vec<f32>> {
    j.as_arr()?.iter().map(|v| hex_to_f32(v.as_str()?)).collect()
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string().context("object key")?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end])?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = text.parse().with_context(|| format!("bad number '{text}'"))?;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"dims":{"c":10,"d":784},"list":[1,2.5,true,null,"s"]}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn usize_vec() {
        let j = Json::parse("[4, 5, 6]").unwrap();
        assert_eq!(j.as_usize_vec().unwrap(), vec![4, 5, 6]);
        assert!(Json::parse("[1.5]").unwrap().as_usize_vec().is_err());
    }

    #[test]
    fn escapes_on_emit() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_string() {
        let j = Json::parse(r#""héllo ☃""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn hex_bit_patterns_round_trip_exactly() {
        for v in [0u64, 1, u64::MAX, 0xdead_beef_cafe_f00d] {
            assert_eq!(hex_to_u64(&u64_to_hex(v)).unwrap(), v);
        }
        for v in [0.0f64, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE, -1e300, f64::NAN] {
            let back = hex_to_f64(&f64_to_hex(v)).unwrap();
            assert_eq!(back.to_bits(), v.to_bits());
        }
        for v in [0.0f32, -0.0, 0.1, f32::MIN_POSITIVE, f32::NAN] {
            let back = hex_to_f32(&f32_to_hex(v)).unwrap();
            assert_eq!(back.to_bits(), v.to_bits());
        }
        let xs = vec![0.25f32, -1.5, 3.0e-8];
        assert_eq!(f32_vec_from_hex(&arr_f32_hex(&xs)).unwrap(), xs);
        let ys = vec![0.1f64, 7.0, -2.5e-11];
        assert_eq!(f64_vec_from_hex(&arr_f64_hex(&ys)).unwrap(), ys);
        assert!(hex_to_u64("zz").is_err());
    }
}
