//! Time-varying client rate processes, layered on the §2.2 delay model.
//!
//! The base [`crate::simnet::topology::Population`] fixes each client's
//! compute rate `mu_j` and per-packet time `tau_j` for the whole run. A
//! [`RateProcess`] modulates those rates *per epoch* with a multiplicative
//! factor — diurnal load curves, per-epoch jitter — modelling the
//! stochastically fluctuating MEC links the paper's setting assumes. The
//! factors are pure functions of `(process, epoch, client, seed)` (or
//! deterministic outright), so modulated runs replay bit-identically and
//! are independent of thread/shard counts.

use anyhow::{bail, ensure, Context, Result};

use crate::mathx::distributions::{Normal, Sample};
use crate::mathx::rng::Rng;

/// Multiplicative jitter clamp: a single epoch can speed a client up or
/// slow it down by at most this factor, keeping delays finite-ish.
const JITTER_CLAMP: f64 = 4.0;

/// A per-epoch multiplicative modulation of client rates (1.0 = base).
#[derive(Debug, Clone, PartialEq)]
pub enum RateProcess {
    /// Rates never change (the paper's setting).
    Static,
    /// Deterministic sinusoidal (diurnal) load curve with client-staggered
    /// phases: client `j`'s factor at `epoch` is
    /// `1 - depth/2 * (1 - cos(2*pi*(epoch/period + j/n)))`, i.e. it
    /// oscillates in `[1 - depth, 1]` with period `period_epochs`.
    Diurnal { period_epochs: f64, depth: f64 },
    /// Independent per-(epoch, client) lognormal jitter:
    /// `factor = exp(sigma * z)`, `z ~ N(0,1)`, clamped to
    /// `[1/JITTER_CLAMP, JITTER_CLAMP]`.
    Jitter { sigma: f64 },
}

impl RateProcess {
    /// `true` when the factor is identically 1 (no modulation at all).
    pub fn is_static(&self) -> bool {
        matches!(self, RateProcess::Static)
    }

    /// Parse a compact spec string:
    ///
    /// * `static`
    /// * `diurnal:PERIOD:DEPTH`
    /// * `jitter:SIGMA`
    pub fn parse(s: &str) -> Result<RateProcess> {
        let s = s.trim();
        if s == "static" || s.is_empty() {
            return Ok(RateProcess::Static);
        }
        if let Some(rest) = s.strip_prefix("diurnal:") {
            let (period, depth) = rest
                .split_once(':')
                .context("diurnal spec is diurnal:PERIOD:DEPTH")?;
            return Ok(RateProcess::Diurnal {
                period_epochs: period.trim().parse().context("diurnal: bad period")?,
                depth: depth.trim().parse().context("diurnal: bad depth")?,
            });
        }
        if let Some(rest) = s.strip_prefix("jitter:") {
            return Ok(RateProcess::Jitter {
                sigma: rest.trim().parse().context("jitter: bad sigma")?,
            });
        }
        bail!("unknown rate process '{s}' (expected static | diurnal:PERIOD:DEPTH | jitter:SIGMA)")
    }

    /// Compact display name (logs, JSONL headers).
    pub fn spec(&self) -> String {
        match self {
            RateProcess::Static => "static".into(),
            RateProcess::Diurnal { period_epochs, depth } => {
                format!("diurnal:{period_epochs}:{depth}")
            }
            RateProcess::Jitter { sigma } => format!("jitter:{sigma}"),
        }
    }

    /// Sanity-check parameters.
    pub fn validate(&self) -> Result<()> {
        match self {
            RateProcess::Static => {}
            RateProcess::Diurnal { period_epochs, depth } => {
                ensure!(*period_epochs > 0.0, "diurnal period must be positive");
                ensure!(
                    (0.0..1.0).contains(depth),
                    "diurnal depth {depth} outside [0, 1)"
                );
            }
            RateProcess::Jitter { sigma } => {
                ensure!(*sigma >= 0.0, "jitter sigma must be non-negative");
            }
        }
        Ok(())
    }

    /// Per-client rate factors for `epoch` (length `n`, all in `(0, 4]`).
    /// `root` must be a dedicated fork of the experiment seed; stochastic
    /// processes draw from `root.fork(epoch)` so each epoch's factors are
    /// independent yet replayable.
    pub fn factors(&self, n: usize, epoch: usize, root: &Rng) -> Vec<f64> {
        match self {
            RateProcess::Static => vec![1.0; n],
            RateProcess::Diurnal { period_epochs, depth } => (0..n)
                .map(|j| {
                    let phase = epoch as f64 / period_epochs + j as f64 / n.max(1) as f64;
                    1.0 - 0.5 * depth * (1.0 - (std::f64::consts::TAU * phase).cos())
                })
                .collect(),
            RateProcess::Jitter { sigma } => {
                let mut r = root.fork(epoch as u64);
                let z = Normal::standard();
                (0..n)
                    .map(|_| {
                        (sigma * z.sample(&mut r)).exp().clamp(1.0 / JITTER_CLAMP, JITTER_CLAMP)
                    })
                    .collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_factors_are_exactly_one() {
        let root = Rng::new(1);
        let f = RateProcess::Static.factors(9, 3, &root);
        assert_eq!(f, vec![1.0; 9]); // exact: the static path must be bitwise-neutral
    }

    #[test]
    fn diurnal_is_bounded_and_periodic() {
        let p = RateProcess::Diurnal { period_epochs: 8.0, depth: 0.5 };
        let root = Rng::new(2);
        for e in 0..20 {
            for &f in &p.factors(10, e, &root) {
                assert!((0.5..=1.0).contains(&f), "factor {f} outside [1-depth, 1]");
            }
        }
        // Same phase one full period later.
        let a = p.factors(10, 1, &root);
        let b = p.factors(10, 9, &root);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
    }

    #[test]
    fn jitter_is_deterministic_clamped_and_varies() {
        let p = RateProcess::Jitter { sigma: 0.5 };
        let root = Rng::new(3);
        let a = p.factors(40, 4, &root);
        let b = p.factors(40, 4, &root);
        assert_eq!(a, b);
        assert!(a.iter().all(|&f| (0.25..=4.0).contains(&f)));
        assert!(a.iter().any(|&f| (f - 1.0).abs() > 1e-3), "jitter did nothing");
        assert_ne!(a, p.factors(40, 5, &root), "epochs share factors");
    }

    #[test]
    fn parse_roundtrip_and_errors() {
        for s in ["static", "diurnal:8:0.4", "jitter:0.2"] {
            let p = RateProcess::parse(s).unwrap();
            assert_eq!(RateProcess::parse(&p.spec()).unwrap(), p);
        }
        assert!(RateProcess::parse("diurnal:8").is_err());
        assert!(RateProcess::parse("sine:1").is_err());
    }

    #[test]
    fn validate_rejects_bad_parameters() {
        assert!(RateProcess::Diurnal { period_epochs: 0.0, depth: 0.2 }.validate().is_err());
        assert!(RateProcess::Diurnal { period_epochs: 4.0, depth: 1.0 }.validate().is_err());
        assert!(RateProcess::Jitter { sigma: -0.1 }.validate().is_err());
        assert!(RateProcess::Static.validate().is_ok());
    }
}
