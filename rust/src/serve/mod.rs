//! `codedfedl serve` — a long-running session server with checkpoint,
//! resume, and fork.
//!
//! One process hosts many concurrent [`crate::scenario::Session`]s
//! behind a line-delimited JSON protocol on localhost TCP
//! ([`protocol`]). Each session runs on its own runner thread driving
//! the cursor-based [`crate::scenario::Session::advance`] loop one round
//! at a time, so round boundaries double as the command-service point
//! and the checkpoint granularity ([`server`]).
//!
//! The protocol methods:
//!
//! | method       | params                                | effect |
//! |--------------|---------------------------------------|--------|
//! | `create`     | `name`, `scenario`? and/or `spec`?    | register a session from a named scenario and/or `[key,value]` spec pairs (validated immediately) |
//! | `start`      | `name`, `watch`?                      | attach a runner thread; optionally subscribe this connection first |
//! | `watch`      | `name`                                | subscribe this connection to the session's event stream |
//! | `status`     | `name`                                | latest per-round status (state, epoch, round, accuracy, model digest) |
//! | `list`       |                                       | all sessions with their states |
//! | `checkpoint` | `name`, `path`?                       | snapshot at the next round boundary (blocks until written) |
//! | `stop`       | `name`, `checkpoint`? (default true)  | stop after the in-flight round, checkpointing first |
//! | `resume`     | `name`, `path`, `watch`?              | restore a snapshot file as a new session and start it |
//! | `fork`       | `name`, `path`, `set`?, `watch`?      | restore with spec overrides — the counterfactual branch |
//! | `shutdown`   |                                       | graceful drain: finish in-flight rounds, checkpoint, exit |
//!
//! Stream lines wrap the **canonical** event documents of
//! [`crate::scenario::observer`] — the same encoder the
//! [`crate::scenario::JsonlObserver`] file format uses — as
//! `{"stream": <session>, "event": <doc>}`, ending with the
//! `"type": "done"` summary document. Because sessions are bitwise
//! deterministic at any thread/shard count, two concurrent sessions on
//! one shared worker pool each reproduce their solo-run streams exactly,
//! and a checkpoint → resume round-trip continues bitwise.

pub mod protocol;
pub mod server;

pub use protocol::{
    err_line, ok_line, parse_request, stream_line, Request,
};
pub use server::{beta_digest, install_sigint_handler, ServeConfig, Server};
