//! Serve-driven sessions: host a training session behind the
//! `codedfedl serve` protocol, watch its live event stream over TCP,
//! checkpoint it at a round boundary, and fork a counterfactual branch
//! off the checkpoint — all in one process.
//!
//!     cargo run --release --example serve_session
//!
//! The same protocol works against a standalone `codedfedl serve`
//! process; here the server is embedded so the example is
//! self-contained. Every stream line wraps the *canonical* event
//! document the JSONL observer writes to files — the wire format and
//! the file format share one encoder.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

use anyhow::{ensure, Result};
use codedfedl::serve::{ServeConfig, Server};
use codedfedl::util::json::Json;

/// Send one request line and read lines until the response, printing
/// any stream events that arrive in between.
fn call(w: &mut TcpStream, r: &mut BufReader<TcpStream>, req: &str) -> Result<Json> {
    writeln!(w, "{req}")?;
    w.flush()?;
    loop {
        let mut line = String::new();
        ensure!(r.read_line(&mut line)? > 0, "server closed the connection");
        let j = Json::parse(line.trim())?;
        if let Some(stream) = j.get("stream") {
            let ev = j.req("event")?;
            let kind = ev.req("type")?.as_str()?;
            if matches!(kind, "eval" | "churn" | "control" | "done") {
                println!("  [{}] {}", stream.as_str()?, ev.to_string());
            }
            continue;
        }
        ensure!(
            j.req("ok")? == &Json::Bool(true),
            "rpc failed: {}",
            j.req("error")?.as_str().unwrap_or("?")
        );
        return Ok(j.req("result")?.clone());
    }
}

/// Block until the named session's stream delivers its `"type": "done"`
/// summary, printing the interesting events along the way.
fn drain_until_done(r: &mut BufReader<TcpStream>, name: &str) -> Result<Json> {
    loop {
        let mut line = String::new();
        ensure!(r.read_line(&mut line)? > 0, "server closed the connection");
        let j = Json::parse(line.trim())?;
        let Some(stream) = j.get("stream") else { continue };
        if stream.as_str()? != name {
            continue;
        }
        let ev = j.req("event")?.clone();
        let kind = ev.req("type")?.as_str()?.to_string();
        if matches!(kind.as_str(), "eval" | "churn" | "control" | "done") {
            println!("  [{name}] {}", ev.to_string());
        }
        if kind == "done" {
            return Ok(ev);
        }
    }
}

fn main() -> Result<()> {
    // 1. Boot the server on an ephemeral port, checkpoints to a temp dir.
    let dir = std::env::temp_dir().join(format!("codedfedl-serve-example-{}", std::process::id()));
    let dir_s = dir.to_str().unwrap().to_string();
    let server = Server::bind(&ServeConfig { port: 0, checkpoint_dir: dir_s.clone() })?;
    let port = server.port();
    println!("serve: listening on 127.0.0.1:{port}");
    let srv = thread::spawn(move || server.run());

    let sock = TcpStream::connect(("127.0.0.1", port))?;
    sock.set_read_timeout(Some(Duration::from_secs(120)))?;
    let mut w = sock.try_clone()?;
    let mut r = BufReader::new(sock);

    // 2. Create + start a churn scenario, watching its live stream on
    // this connection (subscribe-then-start, so nothing is missed).
    call(
        &mut w,
        &mut r,
        r#"{"id":1,"method":"create","params":{"name":"run","scenario":"churn-cells","spec":[["train.epochs","8"]]}}"#,
    )?;
    call(&mut w, &mut r, r#"{"id":2,"method":"start","params":{"name":"run","watch":true}}"#)?;

    // 3. Checkpoint at the next round boundary, mid-run.
    let ckpt = call(
        &mut w,
        &mut r,
        &format!(r#"{{"id":3,"method":"checkpoint","params":{{"name":"run","path":"{dir_s}/run.json"}}}}"#),
    )?;
    let path = ckpt.req("path")?.as_str()?.to_string();
    println!("checkpointed to {path}");

    // 4. Let the original run to completion.
    let done = drain_until_done(&mut r, "run")?;
    println!(
        "original finished: {} steps, final_acc {}",
        done.req("steps")?.as_usize()?,
        done.req("final_accuracy")?.as_f64()?
    );

    // 5. Fork a counterfactual branch off the checkpoint: same shared
    // history, but the branch trains a longer horizon with churn turned
    // off. (An empty "set" would be a bitwise resume instead.)
    call(
        &mut w,
        &mut r,
        &format!(
            r#"{{"id":4,"method":"fork","params":{{"name":"calm","path":"{path}","set":[["scenario.churn","none"],["train.epochs","12"]],"watch":true}}}}"#
        ),
    )?;
    let forked = drain_until_done(&mut r, "calm")?;
    println!(
        "fork finished: {} epochs (extended horizon), final_acc {}",
        forked.req("epochs")?.as_usize()?,
        forked.req("final_accuracy")?.as_f64()?
    );

    // 6. Status + graceful shutdown: the server drains and run() returns.
    let status = call(&mut w, &mut r, r#"{"id":5,"method":"status","params":{"name":"calm"}}"#)?;
    println!("fork status: state={}", status.req("state")?.as_str()?);
    call(&mut w, &mut r, r#"{"id":6,"method":"shutdown"}"#)?;
    srv.join().unwrap()?;
    println!("server drained and shut down cleanly");
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
