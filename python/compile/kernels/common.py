"""Shared helpers for the Pallas kernels.

All kernels in this package are written for the TPU memory model — grids
express an HBM<->VMEM block schedule via BlockSpec — but are lowered with
``interpret=True`` on this image so the resulting HLO runs on the CPU PJRT
client (real-TPU lowering emits a Mosaic custom-call the CPU plugin cannot
execute). Block-shape choices therefore target *structure* (VMEM footprint,
MXU-friendly tiles), not CPU wallclock; see DESIGN.md §Perf.
"""

# TPU-motivated tile targets. The MXU is a 128x128 systolic array; the VPU
# lane width is 128 and the f32 sublane count is 8, so row-block targets are
# multiples of 8 with 128 preferred, and column blocks prefer multiples of
# 128. VMEM is ~16 MiB/core; each kernel documents its footprint.
ROW_BLOCK_TARGET = 128
COL_BLOCK_TARGET = 512


def pick_block(n: int, target: int = ROW_BLOCK_TARGET) -> int:
    """Largest divisor of ``n`` that is <= ``target``.

    Pallas grids require the block shape to tile the array exactly; the
    profiles in aot.py keep dimensions composite so this lands on a
    reasonably large tile (e.g. 100 -> 100, 400 -> 100, 2000 -> 500 with
    target 512).
    """
    if n <= 0:
        raise ValueError(f"dimension must be positive, got {n}")
    if n <= target:
        return n
    best = 1
    for d in range(1, int(n**0.5) + 1):
        if n % d == 0:
            if d <= target:
                best = max(best, d)
            if n // d <= target:
                best = max(best, n // d)
    return best


def vmem_bytes(*shapes, dtype_bytes: int = 4) -> int:
    """Sum of buffer footprints, for the DESIGN.md VMEM estimates."""
    total = 0
    for shape in shapes:
        n = dtype_bytes
        for s in shape:
            n *= s
        total += n
    return total
