//! Load-allocation explorer — reproduces the paper's Figure 1 with the
//! exact parameters from the caption (`p = 0.9`, `tau = sqrt(3)`,
//! `mu = 2`, `t = 10` for 1(a)) and prints/dumps both series:
//!
//!   (a) `E[R_j(t; l)]` vs `l`      — piecewise concavity
//!   (b) `E[R_j(t; l*(t))]` vs `t`  — monotone optimized return
//!
//! ```bash
//! cargo run --release --example load_allocation [-- out_dir]
//! ```

use codedfedl::allocation::expected_return::{expected_return, piece_boundaries};
use codedfedl::allocation::piecewise::optimal_load;
use codedfedl::simnet::delay::ClientModel;
use codedfedl::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let out_dir = std::env::args().nth(1).unwrap_or_else(|| "results".to_string());
    // Figure 1 caption parameters.
    let m = ClientModel { mu: 2.0, alpha: 2.0, tau: 3f64.sqrt(), p_fail: 0.9 };
    let t_fixed = 10.0;

    // (a) expected return vs load at t = 10.
    let mut wa = CsvWriter::create(format!("{out_dir}/fig1a_expected_return.csv"), &["load", "expected_return"])?;
    println!("Fig 1(a): E[R_j(t; l)] vs l at t = {t_fixed} (mu=2, tau=sqrt3, p=0.9)");
    let bounds = piece_boundaries(&m, t_fixed, f64::INFINITY);
    println!("  piece boundaries at l = {bounds:?}");
    let l_max = bounds.first().copied().unwrap_or(10.0) * 1.15;
    let mut best = (0.0, 0.0);
    for i in 0..=400 {
        let l = l_max * i as f64 / 400.0;
        let e = expected_return(&m, l, t_fixed);
        if e > best.1 {
            best = (l, e);
        }
        wa.row_f64(&[l, e])?;
    }
    wa.flush()?;
    let opt = optimal_load(&m, t_fixed, f64::INFINITY);
    println!("  grid max     : E = {:.4} at l = {:.2}", best.1, best.0);
    println!("  optimizer    : E = {:.4} at l = {:.2}", opt.expected, opt.load);

    // (b) optimized expected return vs t.
    let mut wb = CsvWriter::create(format!("{out_dir}/fig1b_monotone.csv"), &["t", "optimized_return", "optimal_load"])?;
    println!("\nFig 1(b): E[R_j(t; l*(t))] vs t (monotone)");
    let mut prev = -1.0;
    let mut monotone = true;
    for i in 1..=120 {
        let t = 0.25 * i as f64;
        let choice = optimal_load(&m, t, f64::INFINITY);
        if choice.expected < prev - 1e-9 {
            monotone = false;
        }
        prev = choice.expected;
        wb.row_f64(&[t, choice.expected, choice.load])?;
        if i % 20 == 0 {
            println!("  t = {t:>6.2}  E* = {:>10.3}  l* = {:>10.2}", choice.expected, choice.load);
        }
    }
    wb.flush()?;
    println!("  monotone: {monotone}");
    println!("\nseries written to {out_dir}/fig1a_expected_return.csv and fig1b_monotone.csv");
    Ok(())
}
