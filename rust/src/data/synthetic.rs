//! Synthetic MNIST/Fashion-MNIST substitutes (DESIGN.md §2).
//!
//! No network access on this image, so we synthesize 10-class datasets
//! that exercise the identical pipeline: `d`-dimensional features in
//! `[0, 1]`, one-hot labels, non-linear class structure. Each class `k`
//! owns a few latent Gaussian sub-clusters ("writing styles"); a sample
//! draws a sub-cluster center plus latent noise and is pushed through a
//! fixed random `tanh` mixing map into feature space. The `tanh` layer
//! makes raw-linear regression clearly inferior to RFF + linear — the
//! paper's Section 3.1 motivation — while RBF-kernel methods separate the
//! classes well.
//!
//! `fashion_like` raises intra-class variance and pulls class centers
//! closer, mirroring Fashion-MNIST being harder than MNIST (lower
//! accuracy ceiling, same shapes).
//!
//! ## Counter-based generation
//!
//! Generation is **counter-based**: the shared world (centers, mixing
//! map, bias) comes from `rng.fork(0)`, the train and test splits own
//! the stream roots `rng.fork(1)` / `rng.fork(2)`, and row `r` of a
//! split is drawn entirely from `root.fork(r)` with its class fixed as
//! `r % c` (no RNG). Any single row can therefore be regenerated in
//! isolation, bitwise-identical to its position in the materialized
//! matrix — the property the hierarchical session's on-demand data path
//! is gated on. [`SyntheticSource`] is that streaming surface: it holds
//! only the world (a few KB) and hands out rows, slices and one-hot
//! label blocks on demand, so a 100k-client population never
//! materializes its `(m_train, d)` matrix.

use crate::data::dataset::Dataset;
use crate::mathx::distributions::{Normal, Sample};
use crate::mathx::linalg::Matrix;
use crate::mathx::rng::Rng;

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    /// Feature dimension (784 to mirror MNIST).
    pub d: usize,
    /// Number of classes.
    pub c: usize,
    /// Latent dimension of the class manifold.
    pub latent: usize,
    /// Sub-clusters ("styles") per class.
    pub styles: usize,
    /// Spread of class centers in latent space.
    pub center_spread: f64,
    /// Latent within-style noise.
    pub noise: f64,
    /// Output-space additive pixel noise.
    pub pixel_noise: f64,
}

impl SynthSpec {
    /// MNIST-like difficulty: separable but not trivially — tuned so the
    /// RFF + linear model plateaus in the mid-90s (%) like real MNIST,
    /// with most of the training run spent climbing (paper Fig. 2).
    pub fn mnist_like(d: usize, c: usize) -> SynthSpec {
        SynthSpec {
            d,
            c,
            latent: 16,
            styles: 3,
            center_spread: 1.75,
            noise: 1.0,
            pixel_noise: 0.06,
        }
    }

    /// Fashion-MNIST-like difficulty: closer classes, more variance —
    /// plateaus several points below the mnist-like ceiling (paper Fig. 3).
    pub fn fashion_like(d: usize, c: usize) -> SynthSpec {
        SynthSpec {
            d,
            c,
            latent: 16,
            styles: 3,
            center_spread: 1.35,
            noise: 1.25,
            pixel_noise: 0.10,
        }
    }
}

/// The fixed "world" shared by train and test splits: class/style centers
/// and the latent->pixel mixing map.
struct World {
    /// `(c * styles, latent)` sub-cluster centers.
    centers: Matrix,
    /// `(latent, d)` mixing map.
    mix: Matrix,
    /// `(1, d)` per-pixel bias.
    bias: Vec<f32>,
}

fn build_world(spec: &SynthSpec, rng: &mut Rng) -> World {
    let centers = Matrix::randn(
        spec.c * spec.styles,
        spec.latent,
        0.0,
        spec.center_spread as f32,
        rng,
    );
    // Scale mixing entries so tanh operates in its non-linear regime.
    let mix = Matrix::randn(spec.latent, spec.d, 0.0, 1.0 / (spec.latent as f32).sqrt(), rng);
    let bias: Vec<f32> = (0..spec.d)
        .map(|_| Normal::new(0.0, 0.3).sample(rng) as f32)
        .collect();
    World { centers, mix, bias }
}

/// Draw one sample of class `class` into `out`, consuming only `rng`
/// (the row's private fork). `latent` is caller-provided scratch of
/// length `spec.latent`.
fn sample_row_into(
    spec: &SynthSpec,
    world: &World,
    class: usize,
    rng: &mut Rng,
    latent: &mut [f32],
    out: &mut [f32],
) {
    let normal = Normal::standard();
    let style = rng.next_below(spec.styles as u64) as usize;
    let center = world.centers.row(class * spec.styles + style);
    for (i, l) in latent.iter_mut().enumerate() {
        *l = center[i] + (normal.sample(rng) * spec.noise) as f32;
    }
    // x = 0.5 * (tanh(latent @ mix + bias) + 1) + pixel noise, clipped.
    for (j, o) in out.iter_mut().enumerate() {
        let mut acc = world.bias[j];
        for (i, &l) in latent.iter().enumerate() {
            acc += l * world.mix.get(i, j);
        }
        let v = 0.5 * (acc.tanh() + 1.0) + (normal.sample(rng) as f32) * spec.pixel_noise as f32;
        *o = v.clamp(0.0, 1.0);
    }
}

fn sample_split(spec: &SynthSpec, world: &World, m: usize, root: &Rng) -> Dataset {
    let mut x = Matrix::zeros(m, spec.d);
    let mut labels = Vec::with_capacity(m);
    let mut latent = vec![0.0f32; spec.latent];
    for r in 0..m {
        // Balanced classes by construction: round-robin assignment.
        let class = r % spec.c;
        let mut row_rng = root.fork(r as u64);
        sample_row_into(spec, world, class, &mut row_rng, &mut latent, x.row_mut(r));
        labels.push(class);
    }
    Dataset::new(x, labels, spec.c).expect("synthetic labels consistent")
}

/// A streaming view of one seeded synthetic (train, test) pair: rows are
/// regenerated on demand from their per-row counter forks instead of
/// living in a resident `(m, d)` matrix. Holds only the world — O(KB)
/// regardless of `m_train`.
///
/// Built from the same base rng as [`generate_pair`], every row it
/// produces is **bitwise identical** to the corresponding row of the
/// materialized dataset (gated by this module's tests and the
/// `scenario_hier` streaming property test).
pub struct SyntheticSource {
    spec: SynthSpec,
    world: World,
    train_root: Rng,
    test_root: Rng,
    m_train: usize,
    m_test: usize,
}

impl SyntheticSource {
    /// Build the source. `rng` is the same base stream `generate_pair`
    /// takes (forking is non-mutating, so both can be built from one
    /// seed and agree bitwise).
    pub fn new(spec: SynthSpec, m_train: usize, m_test: usize, rng: &Rng) -> SyntheticSource {
        let mut world_rng = rng.fork(0);
        let world = build_world(&spec, &mut world_rng);
        SyntheticSource {
            train_root: rng.fork(1),
            test_root: rng.fork(2),
            spec,
            world,
            m_train,
            m_test,
        }
    }

    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.spec.d
    }

    /// Number of classes.
    pub fn n_classes(&self) -> usize {
        self.spec.c
    }

    /// Train-split row count.
    pub fn len_train(&self) -> usize {
        self.m_train
    }

    /// Test-split row count.
    pub fn len_test(&self) -> usize {
        self.m_test
    }

    /// Label of train row `r` — closed-form, no RNG.
    pub fn label(&self, r: usize) -> usize {
        r % self.spec.c
    }

    /// Regenerate train row `r` into `out` (length `d`).
    pub fn train_row_into(&self, r: usize, out: &mut [f32]) {
        debug_assert!(r < self.m_train, "train row {r} out of range {}", self.m_train);
        let mut latent = vec![0.0f32; self.spec.latent];
        let mut row_rng = self.train_root.fork(r as u64);
        sample_row_into(&self.spec, &self.world, r % self.spec.c, &mut row_rng, &mut latent, out);
    }

    /// Materialize the train rows `idx` (in order) as an `(idx.len(), d)`
    /// matrix — the on-demand gather the hierarchical session feeds to
    /// the RFF embed + fused encode-accumulate.
    pub fn train_rows(&self, idx: &[usize]) -> Matrix {
        let mut x = Matrix::zeros(idx.len(), self.spec.d);
        let mut latent = vec![0.0f32; self.spec.latent];
        for (k, &r) in idx.iter().enumerate() {
            debug_assert!(r < self.m_train, "train row {r} out of range {}", self.m_train);
            let mut row_rng = self.train_root.fork(r as u64);
            sample_row_into(
                &self.spec,
                &self.world,
                r % self.spec.c,
                &mut row_rng,
                &mut latent,
                x.row_mut(k),
            );
        }
        x
    }

    /// One-hot labels for the train rows `idx` as an `(idx.len(), c)`
    /// matrix (closed-form — no RNG, no resident label vector).
    pub fn train_one_hot(&self, idx: &[usize]) -> Matrix {
        let mut y = Matrix::zeros(idx.len(), self.spec.c);
        for (k, &r) in idx.iter().enumerate() {
            y.set(k, r % self.spec.c, 1.0);
        }
        y
    }

    /// Materialize the full train split (tests / flat sessions).
    pub fn train_dataset(&self) -> Dataset {
        sample_split(&self.spec, &self.world, self.m_train, &self.train_root)
    }

    /// Materialize the full test split (always resident — evaluation
    /// reads it every eval step and it is small).
    pub fn test_dataset(&self) -> Dataset {
        sample_split(&self.spec, &self.world, self.m_test, &self.test_root)
    }
}

/// Generate a (train, test) pair sharing one world. Deterministic in
/// `rng`; the two splits are disjoint samples from the same distribution.
pub fn generate_pair(
    spec: SynthSpec,
    m_train: usize,
    m_test: usize,
    rng: &mut Rng,
) -> (Dataset, Dataset) {
    let source = SyntheticSource::new(spec, m_train, m_test, rng);
    (source.train_dataset(), source.test_dataset())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(seed: u64) -> (Dataset, Dataset) {
        let mut rng = Rng::new(seed);
        generate_pair(SynthSpec::mnist_like(64, 10), 500, 100, &mut rng)
    }

    #[test]
    fn shapes_and_range() {
        let (tr, te) = gen(1);
        assert_eq!(tr.len(), 500);
        assert_eq!(te.len(), 100);
        assert_eq!(tr.dim(), 64);
        assert!(tr.x.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(te.x.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn classes_are_balanced() {
        let (tr, _) = gen(2);
        let counts = tr.class_counts();
        assert_eq!(counts.len(), 10);
        for &c in &counts {
            assert_eq!(c, 50);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let (a, _) = gen(3);
        let (b, _) = gen(3);
        assert_eq!(a.x, b.x);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn different_seeds_differ() {
        let (a, _) = gen(4);
        let (b, _) = gen(5);
        assert!(a.x != b.x);
    }

    #[test]
    fn streamed_rows_are_bitwise_equal_to_materialized_split() {
        // The on-demand data contract: any subset of rows regenerated
        // through the source matches the same rows of the materialized
        // matrix bit for bit, in any order, and so do the labels.
        let rng = Rng::new(11);
        let spec = SynthSpec::mnist_like(48, 10);
        let source = SyntheticSource::new(spec.clone(), 300, 60, &rng);
        let (tr, te) = {
            let mut r2 = Rng::new(11);
            generate_pair(spec, 300, 60, &mut r2)
        };
        let idx: Vec<usize> = vec![299, 0, 17, 17, 123, 42];
        let got = source.train_rows(&idx);
        for (k, &r) in idx.iter().enumerate() {
            assert_eq!(got.row(k), tr.x.row(r), "streamed row {r} diverged");
            assert_eq!(source.label(r), tr.labels[r]);
        }
        // Single-row entry agrees with the batched gather.
        let mut one = vec![0.0f32; 48];
        source.train_row_into(123, &mut one);
        assert_eq!(&one[..], tr.x.row(123));
        // One-hot blocks match the dataset's derived y.
        let y = source.train_one_hot(&idx);
        for (k, &r) in idx.iter().enumerate() {
            assert_eq!(y.row(k), tr.y.row(r), "one-hot row {r} diverged");
        }
        // Full materializations through the source match generate_pair.
        assert_eq!(source.train_dataset().x, tr.x);
        assert_eq!(source.test_dataset().x, te.x);
    }

    #[test]
    fn classes_are_separated_in_feature_space() {
        // Nearest-class-centroid on raw features should beat chance by a
        // wide margin (the classes carry real signal).
        let (tr, te) = gen(6);
        let d = tr.dim();
        let c = tr.n_classes;
        let mut centroids = Matrix::zeros(c, d);
        let counts = tr.class_counts();
        for r in 0..tr.len() {
            let k = tr.labels[r];
            for j in 0..d {
                let v = centroids.get(k, j) + tr.x.get(r, j) / counts[k] as f32;
                centroids.set(k, j, v);
            }
        }
        let mut hits = 0;
        for r in 0..te.len() {
            let mut best = (f32::INFINITY, 0usize);
            for k in 0..c {
                let dist: f32 = (0..d)
                    .map(|j| (te.x.get(r, j) - centroids.get(k, j)).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, k);
                }
            }
            if best.1 == te.labels[r] {
                hits += 1;
            }
        }
        let acc = hits as f64 / te.len() as f64;
        assert!(acc > 0.5, "centroid accuracy only {acc}");
    }

    #[test]
    fn fashion_variant_is_harder() {
        // Same centroid classifier should do worse on the fashion-like
        // distribution, mirroring MNIST vs Fashion-MNIST difficulty.
        let acc_of = |spec: SynthSpec, seed: u64| {
            let mut rng = Rng::new(seed);
            let (tr, te) = generate_pair(spec, 1000, 300, &mut rng);
            let d = tr.dim();
            let c = tr.n_classes;
            let mut centroids = Matrix::zeros(c, d);
            let counts = tr.class_counts();
            for r in 0..tr.len() {
                let k = tr.labels[r];
                for j in 0..d {
                    let v = centroids.get(k, j) + tr.x.get(r, j) / counts[k] as f32;
                    centroids.set(k, j, v);
                }
            }
            let mut hits = 0;
            for r in 0..te.len() {
                let mut best = (f32::INFINITY, 0usize);
                for k in 0..c {
                    let dist: f32 = (0..d)
                        .map(|j| (te.x.get(r, j) - centroids.get(k, j)).powi(2))
                        .sum();
                    if dist < best.0 {
                        best = (dist, k);
                    }
                }
                if best.1 == te.labels[r] {
                    hits += 1;
                }
            }
            hits as f64 / te.len() as f64
        };
        let mnist = acc_of(SynthSpec::mnist_like(64, 10), 7);
        let fashion = acc_of(SynthSpec::fashion_like(64, 10), 7);
        assert!(
            fashion < mnist,
            "fashion-like ({fashion}) should be harder than mnist-like ({mnist})"
        );
    }
}
