//! Client-private generator matrices `G_j` (paper §3.2): entries i.i.d.
//! `N(0, 1/u)` so that `E[G^T G] = I` — the property that makes the coded
//! gradient an unbiased estimate (paper eq. 11 -> 12).

use crate::mathx::linalg::Matrix;
use crate::mathx::rng::Rng;

/// Sample `G_j` with `u` live parity rows inside a `(u_max, l)` matrix.
///
/// The artifact ABI fixes the parity dimension at `u_max`; when the
/// configured redundancy uses `u < u_max`, rows `u..u_max` are zero and
/// the server masks them out of the coded gradient. Live entries have
/// variance `1/u` (the *live* count — this keeps `E[G^T G] = I`).
pub fn sample_generator(u: usize, u_max: usize, l: usize, rng: &mut Rng) -> Matrix {
    assert!(u > 0 && u <= u_max, "u={u} must be in 1..=u_max={u_max}");
    let sigma = (1.0 / u as f32).sqrt();
    let mut g = Matrix::zeros(u_max, l);
    let live = u * l;
    crate::mathx::distributions::fill_normal_f32(rng, 0.0, sigma, &mut g.data_mut()[..live]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_zero_padding() {
        let mut rng = Rng::new(1);
        let g = sample_generator(4, 10, 6, &mut rng);
        assert_eq!(g.shape(), (10, 6));
        for r in 4..10 {
            assert!(g.row(r).iter().all(|&v| v == 0.0), "row {r} not zero");
        }
        assert!(g.row(0).iter().any(|&v| v != 0.0));
    }

    #[test]
    fn live_rows_have_variance_one_over_u() {
        let mut rng = Rng::new(2);
        let (u, l) = (64, 500);
        let g = sample_generator(u, u, l, &mut rng);
        let n = (u * l) as f64;
        let mean: f64 = g.data().iter().map(|&v| v as f64).sum::<f64>() / n;
        let var: f64 = g.data().iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.003, "mean {mean}");
        assert!((var - 1.0 / u as f64).abs() < 0.002, "var {var}");
    }

    #[test]
    fn gtg_concentrates_to_identity() {
        // The decoding property behind eq. 12 (mirrors the python test).
        let mut rng = Rng::new(3);
        let (u, l) = (4096, 12);
        let g = sample_generator(u, u, l, &mut rng);
        let gtg = g.t_matmul(&g);
        let mut max_err = 0.0f32;
        for r in 0..l {
            for c in 0..l {
                let want = if r == c { 1.0 } else { 0.0 };
                max_err = max_err.max((gtg.get(r, c) - want).abs());
            }
        }
        assert!(max_err < 0.12, "G^T G deviates by {max_err}");
    }

    #[test]
    fn deterministic_in_rng_stream() {
        let a = sample_generator(3, 5, 4, &mut Rng::new(7));
        let b = sample_generator(3, 5, 4, &mut Rng::new(7));
        assert_eq!(a, b);
    }
}
