//! Step-decay learning-rate schedule (paper §A.2: initial step size 6,
//! decay 0.8 at epochs 40 and 65).

/// Multiplicative step-decay schedule.
#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub lr0: f64,
    pub decay: f64,
    /// Epochs at which the rate is multiplied by `decay` (sorted or not).
    pub decay_epochs: Vec<usize>,
}

impl LrSchedule {
    /// Learning rate for (0-based) `epoch`.
    pub fn at(&self, epoch: usize) -> f64 {
        let hits = self.decay_epochs.iter().filter(|&&e| epoch >= e).count();
        self.lr0 * self.decay.powi(hits as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_schedule() {
        let s = LrSchedule { lr0: 6.0, decay: 0.8, decay_epochs: vec![40, 65] };
        assert!((s.at(0) - 6.0).abs() < 1e-12);
        assert!((s.at(39) - 6.0).abs() < 1e-12);
        assert!((s.at(40) - 4.8).abs() < 1e-12);
        assert!((s.at(64) - 4.8).abs() < 1e-12);
        assert!((s.at(65) - 3.84).abs() < 1e-12);
        assert!((s.at(100) - 3.84).abs() < 1e-12);
    }

    #[test]
    fn unsorted_decay_epochs_ok() {
        let s = LrSchedule { lr0: 1.0, decay: 0.5, decay_epochs: vec![8, 2] };
        assert!((s.at(5) - 0.5).abs() < 1e-12);
        assert!((s.at(9) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn no_decay_epochs_is_constant() {
        let s = LrSchedule { lr0: 2.0, decay: 0.1, decay_epochs: vec![] };
        assert_eq!(s.at(1000), 2.0);
    }
}
