//! Persistent worker pool with a **concurrent-job scheduler** for the
//! panel-parallel compute kernels.
//!
//! PR 2 introduced the long-lived workers but serialized jobs behind a
//! run lock: one panel queue in flight at a time, so independent
//! per-client work (gradients, parity encodes, partial returns) queued
//! up behind each other even though their outputs are disjoint. This
//! module replaces the run lock with a shared **job injector**:
//!
//! * **Concurrent jobs.** Any number of callers can submit jobs
//!   ([`WorkerPool::run_tasks`] / [`WorkerPool::run_panels`])
//!   simultaneously; the pool keeps a list of active jobs and idle
//!   workers pick among them round-robin, so sibling jobs run
//!   concurrently instead of serializing. A worker drains the job it
//!   picked before picking again (task-level interleaving is not
//!   guaranteed), but every submitting caller always drives its own
//!   job's queue itself and blocks until that job (and only that job)
//!   is done — no job ever waits behind a sibling for progress.
//! * **Per-job completion + panic isolation.** Completion is tracked per
//!   job (task queue drained + every attached worker detached). A
//!   panicking task poisons *its* job only: remaining tasks of that job
//!   drain without running, the first payload is re-raised on the
//!   submitting caller ([`std::panic::resume_unwind`]), and sibling jobs
//!   — including ones running at the same instant — are untouched. The
//!   pool itself stays usable.
//! * **Determinism.** Which worker executes which task is racy, but task
//!   *splits* are pure functions of the input (e.g. the
//!   [`split_panels`] row split) and tasks write disjoint output
//!   regions with fixed inner reduction order — results are bitwise
//!   identical for any pool size, any task count, and identical to the
//!   scalar oracles.
//! * **Nested submission is safe.** Without a run lock, a task body may
//!   itself submit a job (the nested caller just participates in its own
//!   sub-job); there is no lock to re-enter and no deadlock. The
//!   `mathx::par` kernels still issue their stages from the caller; the
//!   sharded trainer runs per-client kernels inline (single-panel)
//!   inside shard tasks when the batch fills the pool, and falls back to
//!   nested multi-panel jobs only for small batches (few deadline
//!   survivors) so no phase uses fewer lanes than the sequential loop.
//! * **Clean shutdown.** Dropping the pool flags shutdown, wakes every
//!   worker, and **joins** all of them; workers finish the tasks they
//!   already claimed, detach from their jobs, and exit — no detached
//!   threads leak even when the drop races the tail of a job.
//! * **No dependencies.** The offline crate universe has no rayon or
//!   crossbeam; the scoped-lifetime hand-off is a contained `unsafe`
//!   lifetime erasure, sound because the caller never returns before
//!   every worker has detached from its job.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;

use crate::mathx::linalg::MatMut;

/// Lock helper: the pool's internal mutexes never guard user invariants,
/// so a poisoned lock (a panicking task) is safe to keep using.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One job: a task queue plus panic/attachment bookkeeping. Lives on the
/// submitting caller's stack for the duration of one `run_tasks` call;
/// `T` is the task payload (e.g. `(first_row, panel)` for the panel
/// kernels, `(first_index, chunk)` for shard jobs).
struct Job<'k, T> {
    /// Remaining tasks; workers pop from the back (tasks are pushed in
    /// reverse, so execution claims them in submission order).
    tasks: Mutex<Vec<T>>,
    kernel: &'k (dyn Fn(T) + Sync),
    /// First panic payload raised by any task (re-raised on the caller).
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
    /// Set on panic: remaining tasks of THIS job drain without running.
    poisoned: AtomicBool,
    /// Workers currently inside [`RunnableJob::run_until_drained`] for
    /// this job. Mutated only under the pool's state lock; the caller
    /// waits for it to reach zero before letting the job die.
    attached: AtomicUsize,
}

/// Object-safe face of [`Job`] the scheduler sees. `Sync` is a supertrait
/// so a shared reference to a job is `Send` into the worker threads.
trait RunnableJob: Sync {
    fn run_until_drained(&self);
    fn attach(&self);
    fn detach(&self);
    fn attached(&self) -> usize;
}

impl<T: Send> RunnableJob for Job<'_, T> {
    fn run_until_drained(&self) {
        loop {
            let task = lock(&self.tasks).pop();
            let Some(task) = task else { return };
            if self.poisoned.load(Ordering::Relaxed) {
                continue; // a sibling task of THIS job panicked; drain
            }
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| (self.kernel)(task))) {
                self.poisoned.store(true, Ordering::Relaxed);
                let mut slot = lock(&self.panic);
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
        }
    }

    fn attach(&self) {
        self.attached.fetch_add(1, Ordering::Relaxed);
    }

    fn detach(&self) {
        self.attached.fetch_sub(1, Ordering::Relaxed);
    }

    fn attached(&self) -> usize {
        self.attached.load(Ordering::Relaxed)
    }
}

/// SAFETY: callers of [`WorkerPool::run_tasks`] keep the job (and every
/// borrow inside it) alive until all workers have detached, so extending
/// the reference to `'static` for the hand-off through the injector
/// never lets a worker see a dangling job.
unsafe fn erase<'a>(job: &'a (dyn RunnableJob + 'a)) -> &'static (dyn RunnableJob + 'static) {
    std::mem::transmute(job)
}

/// Drop `job` from the active list (no-op if a sibling already did).
fn retract(jobs: &mut Vec<&'static (dyn RunnableJob + 'static)>, job: &'static dyn RunnableJob) {
    jobs.retain(|j| {
        !std::ptr::eq(
            *j as *const dyn RunnableJob as *const (),
            job as *const dyn RunnableJob as *const (),
        )
    });
}

/// State behind the pool's mutex: the active jobs (each may still have
/// queued tasks), a round-robin cursor, and the shutdown flag.
struct Slot {
    jobs: Vec<&'static (dyn RunnableJob + 'static)>,
    /// Round-robin pick cursor so concurrent jobs share the workers
    /// instead of the first job starving the rest.
    rr: usize,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<Slot>,
    /// Workers wait here for a job (or shutdown).
    work_cv: Condvar,
    /// Callers wait here for their job's last attached worker to detach.
    done_cv: Condvar,
}

/// A persistent pool of task workers with concurrent-job scheduling. The
/// process-wide instance is [`global`]; tests build private pools via
/// [`WorkerPool::with_workers`].
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl WorkerPool {
    /// Spawn a pool with `workers` long-lived threads. The caller of
    /// [`WorkerPool::run_tasks`] always participates too, so a pool for
    /// `n`-way parallelism wants `n - 1` workers (and `0` workers means
    /// every job runs inline on its caller).
    pub fn with_workers(workers: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(Slot { jobs: Vec::new(), rr: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let sh = Arc::clone(&shared);
            let h = std::thread::Builder::new()
                .name(format!("codedfedl-pool-{i}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawning pool worker");
            handles.push(h);
        }
        WorkerPool { shared, handles, workers }
    }

    /// Number of long-lived worker threads (each submitting caller adds
    /// one more execution lane on top of these for its own job).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `kernel` over every task in `tasks` as **one job**, using the
    /// pool's workers plus the calling thread, concurrently with any
    /// sibling jobs other callers have in flight. Tasks are claimed in
    /// submission order; the call blocks until every task of THIS job is
    /// done and re-raises the first task panic on the caller.
    ///
    /// With zero or one task, or a worker-less pool, the job runs inline
    /// on the caller in submission order — bitwise the same results,
    /// since tasks must write disjoint state.
    pub fn run_tasks<T, F>(&self, mut tasks: Vec<T>, kernel: F)
    where
        T: Send,
        F: Fn(T) + Sync,
    {
        // Telemetry below is observe-only (host clocks + counters): it
        // never influences scheduling, task order, or results.
        let tel = crate::telemetry::enabled();
        if tasks.len() <= 1 || self.workers == 0 {
            if tel {
                crate::telemetry::counter("pool.jobs_inline").incr();
                crate::telemetry::counter("pool.tasks").add(tasks.len() as u64);
            }
            let _run = if tel {
                crate::telemetry::span("pool.job_run_s")
            } else {
                crate::telemetry::Span::noop()
            };
            for task in tasks {
                kernel(task);
            }
            return;
        }
        if tel {
            crate::telemetry::counter("pool.jobs").incr();
            crate::telemetry::counter("pool.tasks").add(tasks.len() as u64);
        }
        let t0 = tel.then(std::time::Instant::now);
        tasks.reverse(); // pop() claims tasks in submission order
        let job = Job {
            tasks: Mutex::new(tasks),
            kernel: &kernel,
            panic: Mutex::new(None),
            poisoned: AtomicBool::new(false),
            attached: AtomicUsize::new(0),
        };

        // SAFETY: `job` outlives this scope; we retract it from the
        // injector and wait for `attached == 0` before returning, so no
        // worker touches it after it dies (workers attach only while the
        // job is still listed, and both steps happen under the state
        // lock).
        let erased = unsafe { erase(&job) };
        {
            let mut st = lock(&self.shared.state);
            st.jobs.push(erased);
            drop(st);
            self.shared.work_cv.notify_all();
        }

        // The caller is a worker for its own job.
        job.run_until_drained();

        // Occupancy at caller-drain time: workers still attached to this
        // job when its own caller ran out of tasks to claim.
        let drained_at = if let Some(t0) = t0 {
            crate::telemetry::histogram("pool.job_attached", crate::telemetry::count_edges())
                .record(job.attached() as f64);
            crate::telemetry::histogram("pool.job_run_s", crate::telemetry::seconds_edges())
                .record(t0.elapsed().as_secs_f64());
            Some(std::time::Instant::now())
        } else {
            None
        };

        {
            let mut st = lock(&self.shared.state);
            retract(&mut st.jobs, erased); // stop further attaches
            while job.attached() > 0 {
                st = self
                    .shared
                    .done_cv
                    .wait(st)
                    .unwrap_or_else(|e| e.into_inner());
            }
        }
        if let Some(d) = drained_at {
            crate::telemetry::histogram("pool.job_tail_wait_s", crate::telemetry::seconds_edges())
                .record(d.elapsed().as_secs_f64());
        }

        if let Some(payload) = lock(&job.panic).take() {
            resume_unwind(payload);
        }
    }

    /// Split `out` into at most `panels` contiguous row panels and run
    /// `kernel(first_row, panel)` over all of them as one job (the
    /// original panel-kernel entry point, now a [`WorkerPool::run_tasks`]
    /// special case). Blocks until every panel is done; re-raises the
    /// first panel panic on the caller.
    ///
    /// Requesting more panels than the pool has threads is allowed — the
    /// extra panels simply queue (task granularity, not extra threads) —
    /// and the result is bitwise identical either way.
    pub fn run_panels<'env, F>(&self, out: MatMut<'env>, panels: usize, kernel: F)
    where
        F: Fn(usize, MatMut<'env>) + Sync,
    {
        let rows = out.rows();
        let want = panels.max(1).min(rows.max(1));
        self.run_tasks(split_panels(out, want), |(first, panel)| kernel(first, panel));
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        lock(&self.shared.state).shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Deterministic contiguous split: at most `parts` chunks whose sizes
/// differ by at most one, ordered by first element. Pure function of
/// `(len, parts)` — this is what keeps results independent of the pool.
pub(crate) fn split_sizes(len: usize, parts: usize) -> impl Iterator<Item = usize> {
    let n = parts.max(1);
    let base = len / n;
    let rem = len % n;
    (0..n).map(move |p| base + usize::from(p < rem))
}

/// Deterministic panel split over matrix rows (see [`split_sizes`]).
fn split_panels(out: MatMut<'_>, panels: usize) -> Vec<(usize, MatMut<'_>)> {
    let mut tasks = Vec::with_capacity(panels.max(1));
    let mut rest = out;
    let mut first = 0usize;
    for take in split_sizes(rest.rows(), panels) {
        let (head, tail) = rest.split_rows_at(take);
        rest = tail;
        tasks.push((first, head));
        first += take;
    }
    tasks
}

fn worker_loop(shared: &PoolShared) {
    let mut st = lock(&shared.state);
    loop {
        if st.shutdown {
            return;
        }
        if st.jobs.is_empty() {
            st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            continue;
        }
        // Round-robin across active jobs so siblings share the workers.
        let job = st.jobs[st.rr % st.jobs.len()];
        st.rr = st.rr.wrapping_add(1);
        job.attach();
        drop(st);
        job.run_until_drained();
        st = lock(&shared.state);
        // This worker saw the queue drain: retract the spent job so
        // siblings stop attaching to it, then detach and wake callers.
        retract(&mut st.jobs, job);
        job.detach();
        shared.done_cv.notify_all();
    }
}

/// The process-wide pool: `num_threads() - 1` workers (each calling
/// thread is its own extra lane), created on first use and alive for the
/// process lifetime. `CODEDFEDL_THREADS` therefore bounds the pool's
/// *resident* compute threads, exactly as it did under the serialized
/// scheduler; concurrent callers add one lane each for their own jobs.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| {
        WorkerPool::with_workers(crate::mathx::par::num_threads().saturating_sub(1))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mathx::linalg::Matrix;

    #[test]
    fn pool_covers_every_row_exactly_once() {
        let pool = WorkerPool::with_workers(3);
        let mut m = Matrix::zeros(23, 4);
        pool.run_panels(m.view_mut(), 6, |first, mut panel| {
            for pr in 0..panel.rows() {
                let i = first + pr;
                for v in panel.row_mut(pr) {
                    *v += (i + 1) as f32;
                }
            }
        });
        for r in 0..23 {
            assert!(m.row(r).iter().all(|&v| v == (r + 1) as f32), "row {r}");
        }
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = WorkerPool::with_workers(0);
        let mut m = Matrix::zeros(5, 2);
        pool.run_panels(m.view_mut(), 4, |first, mut panel| {
            for pr in 0..panel.rows() {
                panel.row_mut(pr).fill((first + pr) as f32);
            }
        });
        for r in 0..5 {
            assert_eq!(m.row(r), &[r as f32, r as f32]);
        }
    }

    #[test]
    fn sequential_jobs_reuse_the_same_workers() {
        let pool = WorkerPool::with_workers(2);
        for round in 0..50 {
            let mut m = Matrix::zeros(17, 3);
            pool.run_panels(m.view_mut(), 4, |first, mut panel| {
                for pr in 0..panel.rows() {
                    panel.row_mut(pr).fill((round + first + pr) as f32);
                }
            });
            for r in 0..17 {
                assert_eq!(m.row(r)[0], (round + r) as f32, "round {round} row {r}");
            }
        }
    }

    #[test]
    fn generic_task_jobs_run_every_task_once() {
        let pool = WorkerPool::with_workers(2);
        let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
        pool.run_tasks((0..37).collect::<Vec<usize>>(), |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
        }
    }

    #[test]
    fn concurrent_jobs_from_many_threads_complete_independently() {
        let pool = WorkerPool::with_workers(3);
        std::thread::scope(|scope| {
            for t in 0..4usize {
                let pool = &pool;
                scope.spawn(move || {
                    for round in 0..30 {
                        let mut m = Matrix::zeros(19 + t, 3);
                        pool.run_panels(m.view_mut(), 5, |first, mut panel| {
                            for pr in 0..panel.rows() {
                                panel.row_mut(pr).fill((t * 1000 + round + first + pr) as f32);
                            }
                        });
                        for r in 0..m.rows() {
                            assert_eq!(
                                m.row(r)[0],
                                (t * 1000 + round + r) as f32,
                                "thread {t} round {round} row {r}"
                            );
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::with_workers(2);
        let mut m = Matrix::zeros(16, 2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run_panels(m.view_mut(), 4, |first, _panel| {
                if first >= 8 {
                    panic!("injected panel failure");
                }
            });
        }));
        let err = result.expect_err("panel panic must reach the caller");
        let msg = err.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("injected"), "unexpected payload: {msg}");

        // The pool is still fully operational after the poisoned job.
        let mut m2 = Matrix::zeros(9, 2);
        pool.run_panels(m2.view_mut(), 3, |first, mut panel| {
            for pr in 0..panel.rows() {
                panel.row_mut(pr).fill((first + pr) as f32 + 1.0);
            }
        });
        for r in 0..9 {
            assert_eq!(m2.row(r)[0], r as f32 + 1.0);
        }
    }

    #[test]
    fn panic_poisons_only_its_own_job() {
        // A panicking job running concurrently with a healthy sibling
        // must not corrupt the sibling's output or deadlock its caller.
        let pool = WorkerPool::with_workers(3);
        std::thread::scope(|scope| {
            let panicker = {
                let pool = &pool;
                scope.spawn(move || {
                    for _ in 0..20 {
                        let mut bad = Matrix::zeros(24, 2);
                        let caught = catch_unwind(AssertUnwindSafe(|| {
                            pool.run_panels(bad.view_mut(), 6, |first, _p| {
                                if first >= 8 {
                                    panic!("boom");
                                }
                            });
                        }));
                        assert!(caught.is_err(), "panic must reach the submitting caller");
                    }
                })
            };
            let pool = &pool;
            for round in 0..40 {
                let mut ok = Matrix::zeros(33, 2);
                pool.run_panels(ok.view_mut(), 8, |first, mut panel| {
                    for pr in 0..panel.rows() {
                        panel.row_mut(pr).fill((round + first + pr) as f32);
                    }
                });
                for r in 0..33 {
                    assert_eq!(ok.row(r)[0], (round + r) as f32, "round {round} row {r}");
                }
            }
            panicker.join().unwrap();
        });
    }

    #[test]
    fn drop_under_concurrent_load_joins_cleanly() {
        // Many submitters hammer one shared pool; the pool is dropped by
        // whichever Arc holder finishes last, with worker threads still
        // warm from in-flight jobs. Drop must join every worker (no
        // detached-thread leak) without hanging this test.
        let pool = Arc::new(WorkerPool::with_workers(3));
        let mut submitters = Vec::new();
        for t in 0..4usize {
            let p = Arc::clone(&pool);
            submitters.push(std::thread::spawn(move || {
                for round in 0..25 {
                    let mut m = Matrix::zeros(48, 3);
                    p.run_panels(m.view_mut(), 8, |first, mut panel| {
                        for pr in 0..panel.rows() {
                            // A little arithmetic so tasks overlap in time.
                            let mut acc = 0.0f32;
                            for k in 0..64 {
                                acc += ((first + pr + k) as f32).sqrt();
                            }
                            std::hint::black_box(acc);
                            panel.row_mut(pr).fill((t * 100 + round) as f32);
                        }
                    });
                    assert_eq!(m.row(0)[0], (t * 100 + round) as f32);
                }
                // The last submitter to drop its Arc runs WorkerPool::drop
                // right here, with its final job barely finished.
            }));
        }
        drop(pool);
        for h in submitters {
            h.join().unwrap();
        }
    }

    #[test]
    fn global_pool_is_sized_by_thread_knob() {
        let p = global();
        assert_eq!(p.workers(), crate::mathx::par::num_threads().saturating_sub(1));
    }
}
