//! Adaptive-control bench: drift-triggered online load re-allocation vs
//! the static construction plan, on a deterministic drift schedule
//! (`ramp` rate process — the network gets steadily faster than the
//! statistics the static plan was solved with, so the static deadline
//! over-waits every round).
//!
//! Before any timing, the acceptance gate runs: the adaptive session
//! must re-solve at least once and achieve a **lower mean per-round
//! simulated wall-clock** than the static session of the same
//! seed/preset (both are deterministic, so this is a hard invariant,
//! not a statistical one). Then the host-time cells price the control
//! plane itself (estimators + warm re-solves + mask redraws + parity
//! re-encodes).
//!
//! Emits `BENCH_control.json`. Like the `round` and `scenario` cells,
//! this bench refuses to write placeholder numbers.
//!
//! ```bash
//! cargo bench --bench control            # full
//! cargo bench --bench control -- --quick # CI smoke
//! ```

use codedfedl::benchx::Bencher;
use codedfedl::config::Scheme;
use codedfedl::control::ControlPolicy;
use codedfedl::mathx::par;
use codedfedl::runtime::backend::NativeBackend;
use codedfedl::scenario::{EventLog, ScenarioBuilder, SessionSummary};
use codedfedl::simnet::RateProcess;
use codedfedl::util::json::Json;

/// The deterministic drift scenario both variants run: 16 clients whose
/// compute and link rates ramp to 3x the construction-time statistics
/// over 6 epochs. (16 clients keeps u at the full 10% redundancy of the
/// tiny profile — at larger populations u_max pins the redundancy
/// fraction so low that the allocation has no slack to adapt.)
fn builder(epochs: usize) -> anyhow::Result<ScenarioBuilder> {
    let mut b = ScenarioBuilder::from_preset("tiny")?;
    b.set("backend", "native")?;
    Ok(b
        .population(16)
        .steps_per_epoch(2)
        .epochs(epochs)
        .scheme(Scheme::Coded)
        .compute_rates(RateProcess::Ramp { from: 1.0, to: 3.0, ramp_epochs: 6 })
        .link_rates(RateProcess::Ramp { from: 1.0, to: 3.0, ramp_epochs: 6 }))
}

fn adaptive(epochs: usize) -> anyhow::Result<ScenarioBuilder> {
    Ok(builder(epochs)?.adaptive(ControlPolicy::Drift { threshold: 0.05 }))
}

fn run(b: ScenarioBuilder) -> anyhow::Result<(SessionSummary, usize)> {
    let mut session = b.build_with_backend(Box::new(NativeBackend))?;
    let mut log = EventLog::new();
    let summary = session.run_observed(&mut log)?;
    let control_events = log.lines.iter().filter(|l| l.starts_with("control ")).count();
    Ok((summary, control_events))
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let epochs = if quick { 10 } else { 14 };
    let mut b = Bencher::new();
    b.target_time_s = if quick { 0.0 } else { 0.5 };
    b.max_iters = if quick { 1 } else { 3 };
    b.warmup = 0;

    // ---- acceptance gate (deterministic): adaptive re-plans and beats
    // the static plan on mean per-round simulated wall-clock. ----
    let (stat, stat_events) = run(builder(epochs)?)?;
    let (adap, adap_events) = run(adaptive(epochs)?)?;
    assert_eq!(stat.replans, 0, "static session must never re-plan");
    assert_eq!(stat_events, 0, "static session must stream no control events");
    assert!(adap.replans >= 1, "drift policy never re-planned on the ramp schedule");
    assert_eq!(adap_events, adap.replans, "every re-plan must stream a ControlEvent");
    let mean_static = stat.total_sim_time_s / stat.steps as f64;
    let mean_adaptive = adap.total_sim_time_s / adap.steps as f64;
    assert!(
        mean_adaptive <= mean_static,
        "adaptive mean round {mean_adaptive:.4}s exceeds static {mean_static:.4}s"
    );
    println!(
        "gate passed: {} re-plans, mean round {:.4}s adaptive vs {:.4}s static (x{:.2} faster)",
        adap.replans,
        mean_adaptive,
        mean_static,
        mean_static / mean_adaptive
    );

    // ---- host-time cells: what the control plane itself costs. ----
    let static_name = format!("control n=16 static session ({epochs} epochs)");
    b.bench(&static_name, || {
        std::hint::black_box(run(builder(epochs).unwrap()).unwrap());
    });
    let adaptive_name = format!("control n=16 drift session ({epochs} epochs)");
    b.bench(&adaptive_name, || {
        std::hint::black_box(run(adaptive(epochs).unwrap()).unwrap());
    });

    b.report("adaptive control plane (drift-triggered vs static allocation)");
    let mean = |name: &str| {
        b.results().iter().find(|r| r.name == name).map(|r| r.mean_s).unwrap_or(f64::NAN)
    };
    let overhead = mean(&adaptive_name) / mean(&static_name);
    println!(
        "\nadaptive/static host-time ratio: x{overhead:.3} (controller + re-solves + re-encodes)"
    );
    println!(
        "simulated mean round: {mean_adaptive:.4}s adaptive vs {mean_static:.4}s static \
         (deadline tracking win, host-independent)"
    );

    // ---- machine-readable trajectory; refuse placeholder output. ----
    let results: Vec<Json> = b
        .results()
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::Str(r.name.clone())),
                ("iters", Json::Num(r.iters as f64)),
                ("mean_s", Json::Num(r.mean_s)),
                ("p50_s", Json::Num(r.p50_s)),
                ("p95_s", Json::Num(r.p95_s)),
                ("min_s", Json::Num(r.min_s)),
            ])
        })
        .collect();
    anyhow::ensure!(
        !results.is_empty()
            && b.results().iter().all(|r| r.iters >= 1 && r.mean_s.is_finite() && r.mean_s > 0.0),
        "refusing to write BENCH_control.json without real measurements"
    );
    let doc = Json::obj(vec![
        ("bench", Json::Str("control".into())),
        ("status", Json::Str("measured".into())),
        ("quick", Json::Bool(quick)),
        ("clients", Json::Num(16.0)),
        ("epochs", Json::Num(epochs as f64)),
        ("threads_knob", Json::Num(par::num_threads() as f64)),
        ("shards_knob", Json::Num(par::num_shards() as f64)),
        ("policy", Json::Str("drift:0.05".into())),
        ("drift_schedule", Json::Str("ramp:1:3:6 (compute + link)".into())),
        ("replans", Json::Num(adap.replans as f64)),
        ("mean_round_static_s", Json::Num(mean_static)),
        ("mean_round_adaptive_s", Json::Num(mean_adaptive)),
        ("sim_speedup", Json::Num(mean_static / mean_adaptive)),
        ("host_overhead", Json::Num(overhead)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write("BENCH_control.json", doc.to_string())?;
    println!("wrote BENCH_control.json");
    Ok(())
}
