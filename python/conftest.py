# Root conftest: makes the `compile` package importable when running
# `pytest tests/` from python/ (pytest prepends this directory to sys.path).
