//! The federated-learning runtime: per-client state, the learning-rate
//! schedule, and the [`trainer::Trainer`] engine that runs both the
//! uncoded baseline and the CodedFedL scheme over the simulated MEC
//! network. Construction goes through [`crate::scenario`] — the trainer
//! constructors are deprecated shims kept for compatibility.

pub mod embedding;
pub mod lr;
pub mod trainer;

pub use trainer::{SharedData, StepOutcome, Trainer, TrainerSetup};
