//! The session server: many concurrent [`Session`]s, one process.
//!
//! [`Server`] listens on localhost TCP and hosts a registry of named
//! sessions. Each started session runs on its own runner thread, driving
//! the cursor-based [`Session::advance`] loop **one round at a time** so
//! that between any two rounds the runner can (a) answer checkpoint
//! commands, (b) honor a stop request, and (c) notice a server-wide
//! shutdown — the round boundary is simultaneously the command-service
//! point and the checkpoint granularity, which is what makes a serve
//! checkpoint resume bitwise.
//!
//! Sessions are *constructed on the runner thread* (a session's compute
//! backend is not required to be `Send`), so the registry holds only
//! `Send` control state: the command channel, a published status
//! document, and the subscriber list. Event streaming fans each
//! canonical event document out to every subscribed connection; a dead
//! subscriber is dropped and counted, never fatal to the run
//! (`observer_errors` in the final summary reports the losses).
//!
//! Graceful shutdown (the `shutdown` RPC or SIGINT) finishes each
//! session's in-flight round, checkpoints every unfinished session to
//! the checkpoint directory, joins all runners, and returns from
//! [`Server::run`] so the CLI exits 0.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::mathx::linalg::Matrix;
use crate::metrics::EvalRecord;
use crate::scenario::observer::{
    churn_doc, control_doc, epoch_doc, eval_doc, round_doc, summary_doc, ChurnEvent,
    ControlEvent, EpochEvent, RoundEvent, RoundObserver,
};
use crate::scenario::{RunCursor, ScenarioBuilder, Session};
use crate::serve::protocol::{
    err_line, ok_line, param_bool, param_opt_str, param_pairs, param_str, parse_request,
    stream_line, Request,
};
use crate::util::json::Json;

/// Server configuration (the `codedfedl serve` flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// TCP port on 127.0.0.1 (0 = ephemeral; see [`Server::port`]).
    pub port: u16,
    /// Directory shutdown checkpoints and default `checkpoint` paths go
    /// to (created on demand).
    pub checkpoint_dir: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { port: 7070, checkpoint_dir: "serve-checkpoints".into() }
    }
}

/// Where a session's state comes from when its runner builds it.
enum Origin {
    /// A scenario spec: a named scenario and/or `key=value` pairs.
    Spec { scenario: Option<String>, set: Vec<(String, String)> },
    /// A serialized snapshot (the `resume` RPC).
    Snapshot { text: String },
    /// A snapshot plus spec overrides (the `fork` RPC).
    Fork { text: String, set: Vec<(String, String)> },
}

/// Event-stream subscribers: write halves of client connections, shared
/// between the runner (writes) and connection handlers (subscribe).
type Subs = Arc<Mutex<Vec<Arc<Mutex<TcpStream>>>>>;

/// Session runner commands, serviced between rounds.
enum Cmd {
    /// Snapshot to `path`; reply carries the path actually written.
    Checkpoint { path: String, reply: mpsc::Sender<Result<String>> },
    /// Stop after the in-flight round; optionally checkpoint first.
    Stop { checkpoint: bool },
}

/// Registry entry: the `Send` control surface of one session.
struct Entry {
    /// Present until `start` hands it to the runner thread.
    origin: Option<Origin>,
    /// Published status document, updated by the runner each round.
    status: Arc<Mutex<Json>>,
    subs: Subs,
    /// Present while a runner is (or was) attached; a closed channel
    /// means the runner exited.
    cmds: Option<mpsc::Sender<Cmd>>,
    join: Option<thread::JoinHandle<()>>,
}

struct Ctx {
    registry: Mutex<HashMap<String, Entry>>,
    stop: AtomicBool,
    checkpoint_dir: String,
}

/// Process-wide SIGINT latch (see [`install_sigint_handler`]).
static SIGINT: AtomicBool = AtomicBool::new(false);

extern "C" fn on_sigint(_sig: i32) {
    SIGINT.store(true, Ordering::SeqCst);
}

/// Route SIGINT into a graceful serve shutdown: the accept loop notices
/// the latch, stops accepting, checkpoints and joins every running
/// session, and [`Server::run`] returns `Ok` so the process exits 0.
/// Call once from the CLI entry point only — it replaces the process's
/// SIGINT disposition.
pub fn install_sigint_handler() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT_NUM: i32 = 2;
    unsafe {
        signal(SIGINT_NUM, on_sigint as extern "C" fn(i32) as usize);
    }
}

/// Lock helper that survives a poisoned mutex (a panicked peer thread
/// must not wedge the server's control plane).
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// FNV-1a over the model's f32 bit patterns: a cheap order-sensitive
/// digest two runs can compare for bitwise model equality without
/// shipping the matrix.
pub fn beta_digest(m: &Matrix) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in m.data() {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    format!("{h:016x}")
}

/// Fans every canonical event doc out to the session's subscribers as
/// `{"stream", "event"}` lines. Subscriber failures drop that subscriber
/// and count toward [`RoundObserver::error_count`]; they never abort the
/// session (a viewer hanging up must not kill training).
struct StreamFan {
    name: String,
    subs: Subs,
    errors: usize,
}

impl StreamFan {
    fn send(&mut self, doc: Json) {
        // Fan-out cost is a telemetry histogram: a slow or wedged
        // subscriber shows up as serve.fanout_s tail latency.
        let _span = if crate::telemetry::enabled() {
            crate::telemetry::span("serve.fanout_s")
        } else {
            crate::telemetry::Span::noop()
        };
        let line = stream_line(&self.name, doc);
        let mut dropped = 0usize;
        let mut subs = lock(&self.subs);
        subs.retain(|s| {
            let mut w = lock(s);
            let sent = w
                .write_all(line.as_bytes())
                .and_then(|()| w.write_all(b"\n"))
                .and_then(|()| w.flush());
            if sent.is_err() {
                dropped += 1;
            }
            sent.is_ok()
        });
        drop(subs);
        self.errors += dropped;
    }
}

impl RoundObserver for StreamFan {
    fn on_round(&mut self, ev: &RoundEvent) -> Result<()> {
        self.send(round_doc(ev));
        Ok(())
    }
    fn on_eval(&mut self, ev: &EvalRecord) -> Result<()> {
        self.send(eval_doc(ev));
        Ok(())
    }
    fn on_epoch(&mut self, ev: &EpochEvent) -> Result<()> {
        self.send(epoch_doc(ev));
        Ok(())
    }
    fn on_churn(&mut self, ev: &ChurnEvent) -> Result<()> {
        self.send(churn_doc(ev));
        Ok(())
    }
    fn on_control(&mut self, ev: &ControlEvent) -> Result<()> {
        self.send(control_doc(ev));
        Ok(())
    }
    fn on_metrics(&mut self, doc: &Json) -> Result<()> {
        self.send(doc.clone());
        Ok(())
    }
    fn error_count(&self) -> usize {
        self.errors
    }
}

fn publish(status: &Arc<Mutex<Json>>, doc: Json) {
    *lock(status) = doc;
}

fn status_doc(state: &str, session: &Session, cur: &RunCursor, extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("state", Json::Str(state.to_string())),
        ("epoch", Json::Num(cur.epoch() as f64)),
        ("round", Json::Num(cur.rounds_done() as f64)),
        ("sim_time_s", Json::Num(cur.sim_time_s())),
        ("accuracy", Json::Num(cur.last_accuracy())),
        ("beta_digest", Json::Str(beta_digest(session.beta()))),
        ("reencodes", Json::Num(session.reencode_stats().0 as f64)),
        ("replans", Json::Num(session.replans() as f64)),
        ("host_time_s", Json::Num(cur.host_time_s())),
    ];
    // Where the host time went: the top phase timers, process-wide
    // (diagnostic only — absent with telemetry disabled).
    if crate::telemetry::enabled() {
        let top = crate::telemetry::snapshot().top_phases(3);
        pairs.push((
            "phases",
            Json::Arr(
                top.into_iter()
                    .map(|(name, secs)| {
                        Json::obj(vec![
                            ("phase", Json::Str(name)),
                            ("seconds", Json::Num(secs)),
                        ])
                    })
                    .collect(),
            ),
        ));
    }
    pairs.extend(extra);
    Json::obj(pairs)
}

fn build_origin(origin: Origin) -> Result<(Session, RunCursor)> {
    match origin {
        Origin::Spec { scenario, set } => {
            let b = match scenario {
                Some(name) => {
                    let mut b = ScenarioBuilder::named(&name)?;
                    for (k, v) in &set {
                        b.set(k, v)?;
                    }
                    b
                }
                None => ScenarioBuilder::from_spec_pairs(&set)?,
            };
            let session = b.build()?;
            let cur = session.cursor();
            Ok((session, cur))
        }
        Origin::Snapshot { text } => Session::resume_from_str(&text, None),
        Origin::Fork { text, set } => Session::fork_from_str(&text, &set, None),
    }
}

fn write_snapshot(session: &Session, cur: &RunCursor, path: &str) -> Result<()> {
    let text = session.snapshot_string(cur)?;
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
        }
    }
    std::fs::write(path, text + "\n").with_context(|| format!("writing snapshot '{path}'"))?;
    Ok(())
}

/// The per-session runner: build the session from its origin, then
/// alternate (service commands) → (run one round) until done, stopped,
/// or shut down. Runs detached from the registry lock — the only shared
/// state it touches is its own status slot, subscriber list, and command
/// receiver.
fn run_session(
    name: String,
    origin: Origin,
    status: Arc<Mutex<Json>>,
    subs: Subs,
    cmds: mpsc::Receiver<Cmd>,
    ctx: Arc<Ctx>,
) {
    let mut fan = StreamFan { name: name.clone(), subs, errors: 0 };
    let (mut session, mut cur) = match build_origin(origin) {
        Ok(x) => x,
        Err(e) => {
            let msg = format!("{e:#}");
            fan.send(Json::obj(vec![
                ("type", Json::Str("error".into())),
                ("error", Json::Str(msg.clone())),
            ]));
            publish(
                &status,
                Json::obj(vec![
                    ("state", Json::Str("error".into())),
                    ("error", Json::Str(msg)),
                ]),
            );
            return;
        }
    };
    publish(&status, status_doc("running", &session, &cur, vec![]));
    loop {
        // 1. Service commands that arrived since the last round.
        let mut stopping = false;
        let mut stop_checkpoint = true;
        while let Ok(cmd) = cmds.try_recv() {
            match cmd {
                Cmd::Checkpoint { path, reply } => {
                    let r = write_snapshot(&session, &cur, &path).map(|()| path);
                    let _ = reply.send(r);
                }
                Cmd::Stop { checkpoint } => {
                    stopping = true;
                    stop_checkpoint = checkpoint;
                }
            }
        }
        // 2. A server-wide shutdown stops (and checkpoints) everyone.
        if ctx.stop.load(Ordering::SeqCst) {
            stopping = true;
        }
        if stopping {
            if !cur.is_done() && stop_checkpoint {
                let path = format!("{}/{}.json", ctx.checkpoint_dir, name);
                match write_snapshot(&session, &cur, &path) {
                    Ok(()) => publish(
                        &status,
                        status_doc(
                            "checkpointed",
                            &session,
                            &cur,
                            vec![("checkpoint", Json::Str(path))],
                        ),
                    ),
                    Err(e) => publish(
                        &status,
                        status_doc(
                            "error",
                            &session,
                            &cur,
                            vec![("error", Json::Str(format!("{e:#}")))],
                        ),
                    ),
                }
            } else if !cur.is_done() {
                publish(&status, status_doc("stopped", &session, &cur, vec![]));
            }
            return;
        }
        // 3. One round. Everything the round streams goes through the
        // fan; round errors end the session with an error status.
        match session.advance(&mut cur, &mut fan, 1) {
            Ok(k) => {
                if crate::telemetry::enabled() {
                    crate::telemetry::counter("serve.rounds").add(k as u64);
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                fan.send(Json::obj(vec![
                    ("type", Json::Str("error".into())),
                    ("error", Json::Str(msg.clone())),
                ]));
                publish(
                    &status,
                    status_doc("error", &session, &cur, vec![("error", Json::Str(msg))]),
                );
                return;
            }
        }
        if cur.is_done() {
            // The end-of-stream record is the canonical summary doc
            // (`"type": "done"`), then the status carries it too.
            let summary = session.summary(&cur, fan.error_count());
            let done = summary_doc(&summary);
            fan.send(done.clone());
            publish(
                &status,
                status_doc("finished", &session, &cur, vec![("summary", done)]),
            );
            return;
        }
        publish(&status, status_doc("running", &session, &cur, vec![]));
    }
}

/// The `codedfedl serve` server. [`Server::bind`] then [`Server::run`];
/// `run` returns after a `shutdown` RPC or SIGINT completes the graceful
/// drain.
pub struct Server {
    listener: TcpListener,
    ctx: Arc<Ctx>,
}

impl Server {
    /// Bind 127.0.0.1 on the configured port (0 picks an ephemeral one).
    pub fn bind(cfg: &ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", cfg.port))
            .with_context(|| format!("binding 127.0.0.1:{}", cfg.port))?;
        Ok(Server {
            listener,
            ctx: Arc::new(Ctx {
                registry: Mutex::new(HashMap::new()),
                stop: AtomicBool::new(false),
                checkpoint_dir: cfg.checkpoint_dir.clone(),
            }),
        })
    }

    /// The port actually bound (the ephemeral port when configured 0).
    pub fn port(&self) -> u16 {
        self.listener.local_addr().map(|a| a.port()).unwrap_or(0)
    }

    /// Request a graceful shutdown from the hosting process (tests; the
    /// wire path is the `shutdown` RPC, the signal path is SIGINT).
    pub fn request_shutdown(&self) {
        self.ctx.stop.store(true, Ordering::SeqCst);
    }

    /// Accept connections until shutdown, then drain: every running
    /// session finishes its in-flight round, checkpoints to the
    /// checkpoint directory, and is joined before this returns.
    pub fn run(&self) -> Result<()> {
        self.listener.set_nonblocking(true)?;
        loop {
            if SIGINT.load(Ordering::SeqCst) {
                self.ctx.stop.store(true, Ordering::SeqCst);
            }
            if self.ctx.stop.load(Ordering::SeqCst) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _addr)) => {
                    let ctx = self.ctx.clone();
                    thread::spawn(move || handle_conn(stream, ctx));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    thread::sleep(Duration::from_millis(20));
                }
                Err(e) => return Err(e).context("accepting serve connection"),
            }
        }
        // Drain: runners see the stop flag themselves (checkpointing
        // unfinished sessions); joining them makes the drain visible.
        let handles: Vec<(String, thread::JoinHandle<()>)> = {
            let mut reg = lock(&self.ctx.registry);
            reg.iter_mut()
                .filter_map(|(name, e)| e.join.take().map(|h| (name.clone(), h)))
                .collect()
        };
        for (_name, h) in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Every method [`dispatch`] understands, in its match order (also the
/// bound on `serve.rpc.<method>` counter names — an unknown method
/// counts as `serve.rpc.unknown`, so hostile method strings cannot grow
/// the registry).
const METHODS: &[&str] = &[
    "create", "start", "watch", "status", "list", "checkpoint", "stop", "resume", "fork",
    "metrics", "shutdown",
];

/// Telemetry for one RPC: a per-method call counter plus the shared
/// `serve.rpc_s` latency histogram (recorded when the returned span
/// drops, i.e. after dispatch finishes).
fn rpc_span(method: &str) -> crate::telemetry::Span {
    if !crate::telemetry::enabled() {
        return crate::telemetry::Span::noop();
    }
    let m = if METHODS.contains(&method) { method } else { "unknown" };
    crate::telemetry::counter(&format!("serve.rpc.{m}")).incr();
    crate::telemetry::span("serve.rpc_s")
}

/// Per-connection read loop: parse request lines, dispatch, write one
/// response line each. The write half is shared (via `Arc<Mutex<..>>`)
/// with any session streams this connection subscribed to, so responses
/// and stream lines interleave without tearing.
fn handle_conn(stream: TcpStream, ctx: Arc<Ctx>) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let write_half = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if ctx.stop.load(Ordering::SeqCst) {
            return;
        }
        match reader.read_line(&mut line) {
            Ok(0) => return, // client hung up
            Ok(_) => {
                let text = std::mem::take(&mut line);
                if text.trim().is_empty() {
                    continue;
                }
                let reply = match parse_request(&text) {
                    Err(e) => err_line(&Json::Null, &format!("{e:#}")),
                    Ok(req) => {
                        let id = req.id.clone();
                        let result = {
                            let _span = rpc_span(&req.method);
                            dispatch(&req, &write_half, &ctx)
                        };
                        match result {
                            Ok(result) => ok_line(&id, result),
                            Err(e) => err_line(&id, &format!("{e:#}")),
                        }
                    }
                };
                let mut w = lock(&write_half);
                if writeln!(w, "{reply}").and_then(|()| w.flush()).is_err() {
                    return;
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Read timeout: partial input (if any) stays accumulated
                // in `line`; loop to re-check the stop flag.
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

fn valid_name(name: &str) -> Result<()> {
    ensure!(
        !name.is_empty()
            && name.len() <= 64
            && name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.')),
        "session names are 1-64 chars of [A-Za-z0-9._-], got '{name}'"
    );
    Ok(())
}

/// Register a session under `name` and (optionally) immediately attach a
/// runner. Shared by `create`(+`start`) and the one-shot `resume`/`fork`
/// methods.
fn register(
    ctx: &Arc<Ctx>,
    name: &str,
    origin: Origin,
    start_now: bool,
    watcher: Option<Arc<Mutex<TcpStream>>>,
) -> Result<()> {
    valid_name(name)?;
    let mut reg = lock(&ctx.registry);
    ensure!(!reg.contains_key(name), "session '{name}' already exists");
    let mut entry = Entry {
        origin: Some(origin),
        status: Arc::new(Mutex::new(Json::obj(vec![("state", Json::Str("created".into()))]))),
        subs: Arc::new(Mutex::new(Vec::new())),
        cmds: None,
        join: None,
    };
    if let Some(w) = watcher {
        lock(&entry.subs).push(w);
    }
    if start_now {
        start_entry(name, &mut entry, ctx)?;
    }
    reg.insert(name.to_string(), entry);
    Ok(())
}

/// Attach a runner thread to a created entry (origin must still be
/// present — a session starts exactly once).
fn start_entry(name: &str, entry: &mut Entry, ctx: &Arc<Ctx>) -> Result<()> {
    let origin = entry
        .origin
        .take()
        .ok_or_else(|| anyhow!("session '{name}' was already started"))?;
    let (tx, rx) = mpsc::channel();
    entry.cmds = Some(tx);
    let status = entry.status.clone();
    let subs = entry.subs.clone();
    let ctx2 = ctx.clone();
    let name2 = name.to_string();
    entry.join = Some(thread::spawn(move || {
        run_session(name2, origin, status, subs, rx, ctx2)
    }));
    Ok(())
}

fn dispatch(req: &Request, conn: &Arc<Mutex<TcpStream>>, ctx: &Arc<Ctx>) -> Result<Json> {
    let p = &req.params;
    match req.method.as_str() {
        // create {"name", "scenario"?: named scenario, "spec"?: [[k,v],..]}
        // A raw spec must lead with ["preset", name]; a named scenario
        // takes extra pairs via "spec" too.
        "create" => {
            let name = param_str(p, "name")?;
            let scenario = param_opt_str(p, "scenario")?.map(str::to_string);
            let set = param_pairs(p, "spec")?;
            ensure!(
                scenario.is_some() || !set.is_empty(),
                "create needs a 'scenario' (named) or a 'spec' (pairs, leading with preset)"
            );
            // Validate the spec compiles now, so `create` fails fast
            // instead of the runner dying at `start`.
            {
                let b = match &scenario {
                    Some(n) => {
                        let mut b = ScenarioBuilder::named(n)?;
                        for (k, v) in &set {
                            b.set(k, v)?;
                        }
                        b
                    }
                    None => ScenarioBuilder::from_spec_pairs(&set)?,
                };
                b.compile()?;
            }
            register(ctx, name, Origin::Spec { scenario, set }, false, None)?;
            Ok(Json::obj(vec![("name", Json::Str(name.into()))]))
        }
        // start {"name", "watch"?: subscribe this connection first}
        "start" => {
            let name = param_str(p, "name")?;
            let watch = param_bool(p, "watch", false)?;
            let mut reg = lock(&ctx.registry);
            let entry =
                reg.get_mut(name).ok_or_else(|| anyhow!("unknown session '{name}'"))?;
            if watch {
                lock(&entry.subs).push(conn.clone());
            }
            start_entry(name, entry, ctx)?;
            Ok(Json::obj(vec![("name", Json::Str(name.into()))]))
        }
        // watch {"name"}: subscribe this connection to the stream.
        "watch" => {
            let name = param_str(p, "name")?;
            let reg = lock(&ctx.registry);
            let entry = reg.get(name).ok_or_else(|| anyhow!("unknown session '{name}'"))?;
            lock(&entry.subs).push(conn.clone());
            Ok(Json::obj(vec![("name", Json::Str(name.into()))]))
        }
        // status {"name"} -> the runner's latest status document.
        "status" => {
            let name = param_str(p, "name")?;
            let reg = lock(&ctx.registry);
            let entry = reg.get(name).ok_or_else(|| anyhow!("unknown session '{name}'"))?;
            Ok(lock(&entry.status).clone())
        }
        // list -> [{"name", "state"}], name-sorted.
        "list" => {
            let reg = lock(&ctx.registry);
            let mut names: Vec<&String> = reg.keys().collect();
            names.sort();
            Ok(Json::Arr(
                names
                    .into_iter()
                    .map(|n| {
                        let state = lock(&reg[n].status)
                            .get("state")
                            .and_then(|s| s.as_str().ok().map(str::to_string))
                            .unwrap_or_else(|| "unknown".into());
                        Json::obj(vec![
                            ("name", Json::Str(n.clone())),
                            ("state", Json::Str(state)),
                        ])
                    })
                    .collect(),
            ))
        }
        // checkpoint {"name", "path"?}: snapshot at the next round
        // boundary; blocks until written. Default path is
        // <checkpoint_dir>/<name>.json.
        "checkpoint" => {
            let name = param_str(p, "name")?;
            let path = match param_opt_str(p, "path")? {
                Some(s) => s.to_string(),
                None => format!("{}/{}.json", ctx.checkpoint_dir, name),
            };
            let tx = {
                let reg = lock(&ctx.registry);
                let entry =
                    reg.get(name).ok_or_else(|| anyhow!("unknown session '{name}'"))?;
                entry
                    .cmds
                    .clone()
                    .ok_or_else(|| anyhow!("session '{name}' was never started"))?
            };
            let (rtx, rrx) = mpsc::channel();
            tx.send(Cmd::Checkpoint { path, reply: rtx })
                .map_err(|_| anyhow!("session '{name}' is no longer running"))?;
            let written = rrx
                .recv_timeout(Duration::from_secs(120))
                .map_err(|_| anyhow!("session '{name}' did not reach a round boundary"))??;
            Ok(Json::obj(vec![("path", Json::Str(written))]))
        }
        // stop {"name", "checkpoint"?: default true}: stop after the
        // in-flight round (checkpointing first unless told not to).
        "stop" => {
            let name = param_str(p, "name")?;
            let checkpoint = param_bool(p, "checkpoint", true)?;
            let tx = {
                let reg = lock(&ctx.registry);
                let entry =
                    reg.get(name).ok_or_else(|| anyhow!("unknown session '{name}'"))?;
                entry
                    .cmds
                    .clone()
                    .ok_or_else(|| anyhow!("session '{name}' was never started"))?
            };
            tx.send(Cmd::Stop { checkpoint })
                .map_err(|_| anyhow!("session '{name}' is no longer running"))?;
            Ok(Json::obj(vec![("name", Json::Str(name.into()))]))
        }
        // resume {"name", "path", "watch"?}: restore a checkpoint file
        // as a new session and start it immediately.
        "resume" => {
            let name = param_str(p, "name")?;
            let path = param_str(p, "path")?;
            let watch = param_bool(p, "watch", false)?;
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading snapshot '{path}'"))?;
            let watcher = watch.then(|| conn.clone());
            register(ctx, name, Origin::Snapshot { text }, true, watcher)?;
            Ok(Json::obj(vec![("name", Json::Str(name.into()))]))
        }
        // fork {"name", "path", "set"?: [[k,v],..], "watch"?}: restore a
        // checkpoint with spec overrides — the counterfactual branch —
        // and start it immediately.
        "fork" => {
            let name = param_str(p, "name")?;
            let path = param_str(p, "path")?;
            let set = param_pairs(p, "set")?;
            let watch = param_bool(p, "watch", false)?;
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading snapshot '{path}'"))?;
            let watcher = watch.then(|| conn.clone());
            register(ctx, name, Origin::Fork { text, set }, true, watcher)?;
            Ok(Json::obj(vec![("name", Json::Str(name.into()))]))
        }
        // metrics -> the process-wide telemetry snapshot, encoded by the
        // same canonical encoder as the periodic `"type":"metrics"`
        // stream event and the CLI's --metrics-out dump. Served even
        // with telemetry disabled (the snapshot is just empty then).
        "metrics" => Ok(crate::telemetry::snapshot().to_json()),
        // shutdown: graceful server-wide drain (every running session
        // checkpoints); the response is written before the drain begins.
        "shutdown" => {
            ctx.stop.store(true, Ordering::SeqCst);
            Ok(Json::obj(vec![("stopping", Json::Bool(true))]))
        }
        other => bail!(
            "unknown method '{other}' (expected create|start|watch|status|list|checkpoint|\
             stop|resume|fork|metrics|shutdown)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beta_digest_is_order_and_bit_sensitive() {
        let a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let c = Matrix::from_vec(1, 3, vec![3.0, 2.0, 1.0]);
        let d = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0000001]);
        assert_eq!(beta_digest(&a), beta_digest(&b));
        assert_ne!(beta_digest(&a), beta_digest(&c));
        assert_ne!(beta_digest(&a), beta_digest(&d));
        // -0.0 and 0.0 differ in bits, so they must differ in digest.
        let z = Matrix::from_vec(1, 1, vec![0.0]);
        let nz = Matrix::from_vec(1, 1, vec![-0.0]);
        assert_ne!(beta_digest(&z), beta_digest(&nz));
    }

    #[test]
    fn session_names_are_validated() {
        assert!(valid_name("edge-1k.run_2").is_ok());
        assert!(valid_name("").is_err());
        assert!(valid_name("has space").is_err());
        assert!(valid_name("no/slashes").is_err());
        assert!(valid_name(&"x".repeat(65)).is_err());
    }
}
