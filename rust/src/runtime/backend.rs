//! The compute interface the FL trainer codes against, and its pure-rust
//! reference implementation.
//!
//! [`ComputeBackend`] has exactly one method per AOT artifact; the
//! [`crate::runtime::xla::XlaBackend`] executes the HLO artifacts via
//! PJRT, while [`NativeBackend`] evaluates the same math with
//! [`crate::mathx::linalg`]. Integration tests drive both and require
//! agreement, which pins the artifact ABI end-to-end.

use anyhow::{bail, ensure, Result};

use crate::mathx::linalg::{gradient_ref, Matrix};

/// A backend-resident input operand.
///
/// The training hot loop re-feeds the *same* client slices, parity data,
/// masks and test chunks every epoch; preparing them once (for the XLA
/// backend: converting to a `Literal` up front) removes the per-step
/// host-to-literal copy — the §Perf "literal caching" optimization.
pub enum PreparedMatrix {
    /// Plain host matrix (native backend, and the fallback path).
    Native(Matrix),
    /// Pre-built XLA literal plus its logical shape.
    Xla(::xla::Literal, (usize, usize)),
}

impl PreparedMatrix {
    /// Logical (rows, cols).
    pub fn shape(&self) -> (usize, usize) {
        match self {
            PreparedMatrix::Native(m) => m.shape(),
            PreparedMatrix::Xla(_, s) => *s,
        }
    }

    /// Borrow the host matrix (errors for device-prepared operands).
    pub fn as_native(&self) -> Result<&Matrix> {
        match self {
            PreparedMatrix::Native(m) => Ok(m),
            PreparedMatrix::Xla(..) => bail!("operand was prepared for the XLA backend"),
        }
    }
}

/// Compute operations of one shape profile. All matrices are row-major
/// f32; shapes must match the profile exactly (the *callers* pad/mask).
pub trait ComputeBackend {
    /// Masked gradient sum over a client mini-batch slice:
    /// `X^T(mask*(X beta - Y))` with `X: (l, q)`.
    fn grad_client(&self, x: &Matrix, y: &Matrix, beta: &Matrix, mask: &[f32]) -> Result<Matrix>;

    /// Masked gradient sum over the composite parity data, `X: (u_max, q)`.
    fn grad_server(&self, x: &Matrix, y: &Matrix, beta: &Matrix, mask: &[f32]) -> Result<Matrix>;

    /// RFF embedding of one row chunk: `(chunk, d) -> (chunk, q)`.
    fn rff_chunk(&self, x: &Matrix, omega: &Matrix, delta: &Matrix) -> Result<Matrix>;

    /// Parity encoding `G @ (w * M)` with `G: (u_max, l)`, `M: (l, p)`.
    fn encode(&self, g: &Matrix, w: &[f32], m: &Matrix) -> Result<Matrix>;

    /// Ridge step `beta - lr*(grad + lam*beta)`.
    fn update(&self, beta: &Matrix, grad: &Matrix, lr: f32, lam: f32) -> Result<Matrix>;

    /// Logits for one test chunk: `(chunk, q) @ (q, c)`.
    fn predict_chunk(&self, x: &Matrix, beta: &Matrix) -> Result<Matrix>;

    /// Human-readable backend name (for logs and EXPERIMENTS.md).
    fn name(&self) -> &'static str;

    // ---- prepared-operand hot path (defaults: host-matrix passthrough) ----

    /// Prepare a matrix operand for repeated use.
    fn prepare(&self, m: &Matrix) -> Result<PreparedMatrix> {
        Ok(PreparedMatrix::Native(m.clone()))
    }

    /// Prepare a column vector (masks) for repeated use.
    fn prepare_col(&self, v: &[f32]) -> Result<PreparedMatrix> {
        Ok(PreparedMatrix::Native(Matrix::from_vec(v.len(), 1, v.to_vec())))
    }

    /// [`ComputeBackend::grad_client`] over prepared operands (`beta` is
    /// also prepared — once per step, not once per call).
    fn grad_client_p(
        &self,
        x: &PreparedMatrix,
        y: &PreparedMatrix,
        beta: &PreparedMatrix,
        mask: &PreparedMatrix,
    ) -> Result<Matrix> {
        let m = mask.as_native()?;
        self.grad_client(x.as_native()?, y.as_native()?, beta.as_native()?, m.data())
    }

    /// [`ComputeBackend::grad_server`] over prepared operands.
    fn grad_server_p(
        &self,
        x: &PreparedMatrix,
        y: &PreparedMatrix,
        beta: &PreparedMatrix,
        mask: &PreparedMatrix,
    ) -> Result<Matrix> {
        let m = mask.as_native()?;
        self.grad_server(x.as_native()?, y.as_native()?, beta.as_native()?, m.data())
    }

    /// [`ComputeBackend::predict_chunk`] over a prepared chunk.
    fn predict_chunk_p(&self, x: &PreparedMatrix, beta: &PreparedMatrix) -> Result<Matrix> {
        self.predict_chunk(x.as_native()?, beta.as_native()?)
    }

    /// RFF-embed an arbitrary number of rows by streaming `chunk`-row
    /// slices through [`ComputeBackend::rff_chunk`], zero-padding the tail.
    fn rff_embed_all(&self, x: &Matrix, omega: &Matrix, delta: &Matrix, chunk: usize)
        -> Result<Matrix> {
        let (m, d) = x.shape();
        let q = omega.cols();
        let mut out = Matrix::zeros(m, q);
        let mut row = 0;
        while row < m {
            let take = chunk.min(m - row);
            let mut padded = Matrix::zeros(chunk, d);
            for r in 0..take {
                padded.row_mut(r).copy_from_slice(x.row(row + r));
            }
            let emb = self.rff_chunk(&padded, omega, delta)?;
            ensure!(emb.shape() == (chunk, q), "rff chunk shape {:?}", emb.shape());
            for r in 0..take {
                out.row_mut(row + r).copy_from_slice(emb.row(r));
            }
            row += take;
        }
        Ok(out)
    }

    /// Predict logits for an arbitrary number of rows (streamed, padded).
    fn predict_all(&self, x: &Matrix, beta: &Matrix, chunk: usize) -> Result<Matrix> {
        let (m, q) = x.shape();
        let c = beta.cols();
        let mut out = Matrix::zeros(m, c);
        let mut row = 0;
        while row < m {
            let take = chunk.min(m - row);
            let mut padded = Matrix::zeros(chunk, q);
            for r in 0..take {
                padded.row_mut(r).copy_from_slice(x.row(row + r));
            }
            let logits = self.predict_chunk(&padded, beta)?;
            for r in 0..take {
                out.row_mut(row + r).copy_from_slice(logits.row(r));
            }
            row += take;
        }
        Ok(out)
    }
}

/// Pure-rust implementation over [`crate::mathx::linalg`]. Exact same math
/// as the artifacts; used as the test oracle and for artifact-free runs
/// (`use_xla = false`).
pub struct NativeBackend;

impl ComputeBackend for NativeBackend {
    fn grad_client(&self, x: &Matrix, y: &Matrix, beta: &Matrix, mask: &[f32]) -> Result<Matrix> {
        Ok(gradient_ref(x, y, beta, mask))
    }

    fn grad_server(&self, x: &Matrix, y: &Matrix, beta: &Matrix, mask: &[f32]) -> Result<Matrix> {
        Ok(gradient_ref(x, y, beta, mask))
    }

    fn rff_chunk(&self, x: &Matrix, omega: &Matrix, delta: &Matrix) -> Result<Matrix> {
        let q = omega.cols();
        ensure!(delta.shape() == (1, q), "delta shape");
        let scale = (2.0f32 / q as f32).sqrt();
        let mut out = x.matmul(omega);
        for r in 0..out.rows() {
            let row = out.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                *v = scale * (*v + delta.get(0, c)).cos();
            }
        }
        Ok(out)
    }

    fn encode(&self, g: &Matrix, w: &[f32], m: &Matrix) -> Result<Matrix> {
        Ok(g.matmul(&m.scale_rows(w)))
    }

    fn update(&self, beta: &Matrix, grad: &Matrix, lr: f32, lam: f32) -> Result<Matrix> {
        // beta - lr*(grad + lam*beta) = (1 - lr*lam)*beta - lr*grad
        Ok(beta.scale(1.0 - lr * lam).axpy(-lr, grad))
    }

    fn predict_chunk(&self, x: &Matrix, beta: &Matrix) -> Result<Matrix> {
        Ok(x.matmul(beta))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mathx::rng::Rng;

    #[test]
    fn native_update_math() {
        let beta = Matrix::from_vec(2, 1, vec![1.0, 2.0]);
        let grad = Matrix::from_vec(2, 1, vec![0.5, -0.5]);
        let nb = NativeBackend;
        let out = nb.update(&beta, &grad, 0.1, 0.01).unwrap();
        // (1 - 0.001)*beta - 0.1*grad
        assert!((out.get(0, 0) - (0.999 - 0.05)).abs() < 1e-6);
        assert!((out.get(1, 0) - (1.998 + 0.05)).abs() < 1e-6);
    }

    #[test]
    fn native_rff_is_bounded_and_scaled() {
        let mut rng = Rng::new(1);
        let x = Matrix::randn(5, 3, 0.0, 1.0, &mut rng);
        let omega = Matrix::randn(3, 8, 0.0, 1.0, &mut rng);
        let delta = Matrix::randn(1, 8, 3.0, 1.0, &mut rng);
        let out = NativeBackend.rff_chunk(&x, &omega, &delta).unwrap();
        let bound = (2.0f32 / 8.0).sqrt() + 1e-6;
        assert!(out.data().iter().all(|v| v.abs() <= bound));
    }

    #[test]
    fn streamed_embed_handles_ragged_tail() {
        let mut rng = Rng::new(2);
        let x = Matrix::randn(7, 3, 0.0, 1.0, &mut rng); // 7 rows, chunk 4
        let omega = Matrix::randn(3, 6, 0.0, 1.0, &mut rng);
        let delta = Matrix::randn(1, 6, 0.0, 1.0, &mut rng);
        let nb = NativeBackend;
        let streamed = nb.rff_embed_all(&x, &omega, &delta, 4).unwrap();
        let whole = nb.rff_chunk(&x, &omega, &delta).unwrap();
        assert!(streamed.max_abs_diff(&whole) < 1e-6);
    }

    #[test]
    fn streamed_predict_matches_direct() {
        let mut rng = Rng::new(3);
        let x = Matrix::randn(9, 4, 0.0, 1.0, &mut rng);
        let beta = Matrix::randn(4, 3, 0.0, 1.0, &mut rng);
        let nb = NativeBackend;
        let streamed = nb.predict_all(&x, &beta, 4).unwrap();
        assert!(streamed.max_abs_diff(&x.matmul(&beta)) < 1e-6);
    }

    #[test]
    fn encode_equals_weighted_matmul() {
        let mut rng = Rng::new(4);
        let g = Matrix::randn(3, 5, 0.0, 1.0, &mut rng);
        let m = Matrix::randn(5, 2, 0.0, 1.0, &mut rng);
        let w = vec![1.0, 0.5, 0.0, 2.0, 1.0];
        let got = NativeBackend.encode(&g, &w, &m).unwrap();
        assert!(got.max_abs_diff(&g.matmul(&m.scale_rows(&w))) < 1e-6);
    }
}
