"""Tests for the kernel tiling helpers (block picking + VMEM accounting)."""

import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels.common import pick_block, vmem_bytes


def test_pick_block_small_passthrough():
    assert pick_block(20) == 20
    assert pick_block(1) == 1
    assert pick_block(128) == 128


def test_pick_block_prefers_large_divisors():
    assert pick_block(400, 128) == 100
    assert pick_block(2000, 512) == 500
    assert pick_block(256, 128) == 128
    assert pick_block(900, 128) == 100


def test_pick_block_prime_falls_back_to_one():
    # 251 is prime and > target -> only divisor <= 128 is 1.
    assert pick_block(251, 128) == 1


def test_pick_block_rejects_nonpositive():
    with pytest.raises(ValueError):
        pick_block(0)


@given(n=st.integers(1, 5000), target=st.sampled_from([8, 64, 128, 512]))
def test_pick_block_is_valid_divisor(n, target):
    b = pick_block(n, target)
    assert b >= 1
    assert n % b == 0
    # Either the block respects the target, or the whole array fit in one
    # block to begin with.
    assert b <= target or b == n


def test_vmem_bytes_accounts_f32():
    # gradient kernel @ paper shapes (see kernels/gradient.py header).
    total = vmem_bytes((128, 2000), (128, 10), (2000, 10), (128, 1), (2000, 10))
    assert total == 4 * (128 * 2000 + 128 * 10 + 2000 * 10 + 128 + 2000 * 10)
    assert total < 16 * 2**20  # fits VMEM


def test_profile_block_choices_fit_vmem():
    # Every shipped profile's gradient tile must fit a 16 MiB VMEM budget.
    from compile.aot import PROFILES

    for name, p in PROFILES.items():
        blk = pick_block(p["l"])
        total = vmem_bytes(
            (blk, p["q"]), (blk, p["c"]), (p["q"], p["c"]), (blk, 1), (p["q"], p["c"])
        )
        assert total < 16 * 2**20, f"{name}: gradient tile {total} bytes"
