//! Discrete-event trace of one training epoch — per-client timelines of
//! model broadcast, retransmissions, compute, and upload.
//!
//! The trainer only needs epoch *totals* (sampled in [`crate::simnet::delay`]);
//! this module expands the same stochastic model into an event log, used
//! by the `codedfedl trace` subcommand for debugging/visualization and by
//! tests that validate the component decomposition against the totals.

use crate::mathx::distributions::{Exponential, Geometric, Sample};
use crate::mathx::rng::Rng;
use crate::simnet::delay::ClientModel;

/// Event kinds in a client's epoch timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A downlink transmission attempt (model broadcast to the client).
    DownlinkAttempt { attempt: u32, success: bool },
    /// Local gradient computation (deterministic + stochastic parts).
    Compute,
    /// An uplink transmission attempt (gradient to the server).
    UplinkAttempt { attempt: u32, success: bool },
}

/// One timeline event.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub client: usize,
    pub kind: EventKind,
    /// Event start, seconds from epoch start.
    pub start: f64,
    /// Event end.
    pub end: f64,
}

/// One client's full epoch timeline.
#[derive(Debug, Clone)]
pub struct ClientTrace {
    pub client: usize,
    pub events: Vec<Event>,
    /// Time the gradient lands at the server.
    pub finish: f64,
}

/// Expand one epoch into event timelines. Statistically identical to
/// [`ClientModel::sample`]: same distributions, same parameters.
pub fn trace_epoch(
    models: &[ClientModel],
    loads: &[usize],
    rng: &mut Rng,
) -> Vec<ClientTrace> {
    assert_eq!(models.len(), loads.len());
    let mut traces = Vec::with_capacity(models.len());
    for (j, (m, &load)) in models.iter().zip(loads).enumerate() {
        let mut events = Vec::new();
        let mut t = 0.0f64;
        let geo = Geometric::new(m.p_fail);

        // Downlink attempts until the first success.
        let n_down = geo.sample_trials(rng) as u32;
        for a in 1..=n_down {
            let end = t + m.tau;
            events.push(Event {
                client: j,
                kind: EventKind::DownlinkAttempt { attempt: a, success: a == n_down },
                start: t,
                end,
            });
            t = end;
        }

        // Compute.
        if load > 0 {
            let dur = load as f64 / m.mu
                + Exponential::new(m.alpha * m.mu / load as f64).sample(rng);
            events.push(Event { client: j, kind: EventKind::Compute, start: t, end: t + dur });
            t += dur;
        }

        // Uplink attempts until the first success.
        let n_up = geo.sample_trials(rng) as u32;
        for a in 1..=n_up {
            let end = t + m.tau;
            events.push(Event {
                client: j,
                kind: EventKind::UplinkAttempt { attempt: a, success: a == n_up },
                start: t,
                end,
            });
            t = end;
        }

        traces.push(ClientTrace { client: j, events, finish: t });
    }
    traces
}

/// Write traces as CSV rows: client, kind, attempt, success, start, end.
pub fn write_csv<W: std::io::Write>(traces: &[ClientTrace], out: W) -> anyhow::Result<()> {
    let mut w = crate::util::csv::CsvWriter::new(
        out,
        &["client", "kind", "attempt", "success", "start_s", "end_s"],
    )?;
    for tr in traces {
        for e in &tr.events {
            let (kind, attempt, success) = match e.kind {
                EventKind::DownlinkAttempt { attempt, success } => ("downlink", attempt, success),
                EventKind::Compute => ("compute", 0, true),
                EventKind::UplinkAttempt { attempt, success } => ("uplink", attempt, success),
            };
            w.row(&[
                e.client.to_string(),
                kind.to_string(),
                attempt.to_string(),
                success.to_string(),
                format!("{:.6}", e.start),
                format!("{:.6}", e.end),
            ])?;
        }
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mathx::stats::OnlineStats;

    fn model() -> ClientModel {
        ClientModel { mu: 100.0, alpha: 2.0, tau: 0.05, p_fail: 0.3 }
    }

    #[test]
    fn timeline_is_contiguous_and_ordered() {
        let mut rng = Rng::new(1);
        let traces = trace_epoch(&[model(), model()], &[50, 20], &mut rng);
        for tr in &traces {
            let mut t = 0.0;
            for e in &tr.events {
                assert!((e.start - t).abs() < 1e-12, "gap in timeline");
                assert!(e.end >= e.start);
                t = e.end;
            }
            assert!((tr.finish - t).abs() < 1e-12);
        }
    }

    #[test]
    fn exactly_one_successful_attempt_per_direction() {
        let mut rng = Rng::new(2);
        let traces = trace_epoch(&[model()], &[30], &mut rng);
        let tr = &traces[0];
        let down_succ = tr.events.iter().filter(|e| matches!(e.kind, EventKind::DownlinkAttempt { success: true, .. })).count();
        let up_succ = tr.events.iter().filter(|e| matches!(e.kind, EventKind::UplinkAttempt { success: true, .. })).count();
        assert_eq!(down_succ, 1);
        assert_eq!(up_succ, 1);
        // The successful attempt is the last one in each direction.
        let last_down = tr.events.iter().rev().find_map(|e| match e.kind {
            EventKind::DownlinkAttempt { success, .. } => Some(success),
            _ => None,
        });
        assert_eq!(last_down, Some(true));
    }

    #[test]
    fn finish_distribution_matches_total_sampler() {
        // The trace's finish time must follow the same distribution as
        // ClientModel::sample().total(): compare means over many epochs.
        let m = model();
        let mut rng1 = Rng::new(3);
        let mut rng2 = Rng::new(4);
        let mut s_trace = OnlineStats::new();
        let mut s_total = OnlineStats::new();
        for _ in 0..30_000 {
            s_trace.push(trace_epoch(std::slice::from_ref(&m), &[40], &mut rng1)[0].finish);
            s_total.push(m.sample(40, &mut rng2).total());
        }
        let diff = (s_trace.mean() - s_total.mean()).abs();
        assert!(diff < 6.0 * (s_trace.sem() + s_total.sem()), "means differ: {diff}");
    }

    #[test]
    fn zero_load_has_no_compute_event() {
        let mut rng = Rng::new(5);
        let traces = trace_epoch(&[model()], &[0], &mut rng);
        assert!(traces[0].events.iter().all(|e| e.kind != EventKind::Compute));
    }

    #[test]
    fn csv_emission() {
        let mut rng = Rng::new(6);
        let traces = trace_epoch(&[model()], &[10], &mut rng);
        let mut buf = Vec::new();
        write_csv(&traces, &mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("client,kind,attempt,success,start_s,end_s\n"));
        assert!(text.contains("compute"));
        assert!(text.lines().count() >= 4);
    }
}
