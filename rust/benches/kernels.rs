//! Kernel benchmarks: the cache-blocked multi-threaded compute core vs
//! the seed's scalar kernels, across sizes and thread counts.
//!
//! Cells:
//!   * `matmul` — square `s x s x s` products (s = 128, 256, 512);
//!   * `t_matmul` — the gradient's second stage shape, `(m, q)^T (m, c)`;
//!   * `gather-gradient` — the per-client masked gradient over a row-index
//!     set, seed path (select_rows + scalar gradient) vs the zero-copy
//!     blocked kernel.
//!
//! Each blocked cell runs at 1/2/4/8 threads regardless of
//! `CODEDFEDL_THREADS`; a speedup summary vs the scalar baseline is
//! printed at the end.
//!
//! ```bash
//! cargo bench --bench kernels
//! ```

use codedfedl::benchx::Bencher;
use codedfedl::mathx::linalg::{gradient_naive, matmul_naive, t_matmul_naive, Matrix};
use codedfedl::mathx::par;
use codedfedl::mathx::rng::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 8];

fn mean_of(b: &Bencher, name: &str) -> f64 {
    b.results()
        .iter()
        .find(|r| r.name == name)
        .map(|r| r.mean_s)
        .unwrap_or(f64::NAN)
}

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new();
    b.target_time_s = 0.25;
    b.max_iters = 40;
    b.warmup = 1;
    let mut rng = Rng::new(7);
    let mut summaries: Vec<(String, String)> = Vec::new();

    // --- square matmul across sizes and thread counts.
    for &s in &[128usize, 256, 512] {
        let a = Matrix::randn(s, s, 0.0, 1.0, &mut rng);
        let c = Matrix::randn(s, s, 0.0, 1.0, &mut rng);
        let flops = 2.0 * (s * s * s) as f64;
        let base = format!("matmul {s}x{s}x{s} scalar (seed)");
        b.bench_with_work(&base, Some(flops), || {
            std::hint::black_box(matmul_naive(a.view(), c.view()));
        });
        for &t in &THREADS {
            b.bench_with_work(&format!("matmul {s}x{s}x{s} blocked {t}t"), Some(flops), || {
                std::hint::black_box(par::matmul_with_threads(a.view(), c.view(), t));
            });
        }
        let naive = mean_of(&b, &base);
        let best4 = mean_of(&b, &format!("matmul {s}x{s}x{s} blocked 4t"));
        summaries.push((
            format!("matmul {s}"),
            format!("x{:.2} at 4 threads vs seed scalar", naive / best4),
        ));
    }

    // --- transposed matmul (gradient stage 2 shape: m=4096, q=512, c=10).
    {
        let (m, q, c) = (4096usize, 512usize, 10usize);
        let a = Matrix::randn(m, q, 0.0, 1.0, &mut rng);
        let e = Matrix::randn(m, c, 0.0, 1.0, &mut rng);
        let flops = 2.0 * (m * q * c) as f64;
        b.bench_with_work("t_matmul 4096x512^T @ 4096x10 scalar (seed)", Some(flops), || {
            std::hint::black_box(t_matmul_naive(a.view(), e.view()));
        });
        for &t in &THREADS {
            let name = format!("t_matmul 4096x512^T @ 4096x10 blocked {t}t");
            b.bench_with_work(&name, Some(flops), || {
                std::hint::black_box(par::t_matmul_with_threads(a.view(), e.view(), t));
            });
        }
    }

    // --- gather-gradient: per-client masked gradient over a row set.
    {
        let (m_total, l, q, c) = (12_288usize, 512usize, 512usize, 10usize);
        let x = Matrix::randn(m_total, q, 0.0, 1.0, &mut rng);
        let y = Matrix::randn(m_total, c, 0.0, 1.0, &mut rng);
        let beta = Matrix::randn(q, c, 0.0, 0.3, &mut rng);
        let idx: Vec<usize> = (0..l).map(|i| (i * 23) % m_total).collect();
        let mask: Vec<f32> = (0..l).map(|i| if i % 5 == 0 { 0.0 } else { 1.0 }).collect();
        let flops = 4.0 * (l * q * c) as f64;

        let base = "gather-grad 512 rows of 12288x512 scalar (seed select_rows)";
        b.bench_with_work(base, Some(flops), || {
            let xs = x.select_rows(&idx);
            let ys = y.select_rows(&idx);
            std::hint::black_box(gradient_naive(&xs, &ys, &beta, &mask).unwrap());
        });
        for &t in &THREADS {
            b.bench_with_work(
                &format!("gather-grad 512 rows of 12288x512 blocked {t}t"),
                Some(flops),
                || {
                    std::hint::black_box(
                        par::gather_gradient_with_threads(
                            x.view(),
                            y.view(),
                            &idx,
                            beta.view(),
                            &mask,
                            t,
                        )
                        .unwrap(),
                    );
                },
            );
        }
        let naive = mean_of(&b, base);
        let best4 = mean_of(&b, "gather-grad 512 rows of 12288x512 blocked 4t");
        summaries.push((
            "gather-gradient".into(),
            format!("x{:.2} at 4 threads vs seed scalar", naive / best4),
        ));
    }

    b.report("kernel benchmarks (blocked/parallel vs seed scalar)");
    println!("\nspeedup summary:");
    for (what, line) in &summaries {
        println!("  {what:<16} {line}");
    }
    println!("(host has {} available threads)", par::num_threads());
    Ok(())
}
