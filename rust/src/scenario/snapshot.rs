//! The versioned session-snapshot format (`codedfedl-snapshot` v1).
//!
//! A snapshot is one JSON object capturing *everything* a
//! [`crate::scenario::Session`] needs to resume a run **bitwise
//! identically** at a round boundary:
//!
//! * the scenario's recorded spec pairs ([`crate::scenario::Scenario::
//!   spec`]) — construction is replayed, never serialized, so a snapshot
//!   stays small no matter the population;
//! * the [`RunCursor`] — where in the epoch/step grid the run stands,
//!   plus the streaming aggregates (sim clock, arrival fractions, eval
//!   count) that feed the final [`crate::scenario::SessionSummary`];
//! * the engine's mutable state — the model (f32 bit patterns) and the
//!   delay stream's raw xoshiro words, the only sequentially-mutated rng
//!   in the system (every other stream is counter-based and re-derived);
//! * parity provenance — which `(stream_base, active set)` re-encode is
//!   in force, replayed on restore rather than shipping the encoded
//!   matrices;
//! * the control plane — replan count, the allocation in force, and the
//!   controller's estimator/diagnostic state.
//!
//! Every float crosses the wire as a hex bit pattern
//! ([`crate::util::json`] helpers), so restore is exact, not
//! shortest-decimal-close. The snapshot/restore/fork entry points live
//! on [`crate::scenario::Session`]; this module owns the cursor type,
//! the format constants, and the leaf encoders.

use anyhow::{ensure, Result};

use crate::mathx::linalg::Matrix;
use crate::util::json::{self as uj, Json};

/// `"format"` tag every snapshot document carries.
pub const SNAPSHOT_FORMAT: &str = "codedfedl-snapshot";
/// Current snapshot schema version. Bump on any incompatible change;
/// restore rejects versions it does not understand.
pub const SNAPSHOT_VERSION: usize = 1;

/// Resumable position in a session's epoch/step grid plus the streaming
/// aggregates of the run so far. Obtained from
/// [`crate::scenario::Session::cursor`], advanced by
/// [`crate::scenario::Session::advance`], and embedded verbatim in
/// snapshots. `batch` is the next step *within* the current epoch to
/// execute (`0` = the epoch's begin-of-epoch work — churn roster,
/// control decision, parity re-encode — has not run yet).
#[derive(Debug, Clone)]
pub struct RunCursor {
    pub(crate) epoch: usize,
    pub(crate) batch: usize,
    pub(crate) global_step: usize,
    pub(crate) sim_time_s: f64,
    pub(crate) arrival_frac_sum: f64,
    pub(crate) evals: usize,
    pub(crate) last_accuracy: f64,
    pub(crate) fault_aborts: usize,
    pub(crate) telemetry_drops: usize,
    /// Roster of the previously-completed epoch (churn transitions are
    /// emitted against it).
    pub(crate) prev_active: Vec<usize>,
    pub(crate) done: bool,
    /// Host seconds spent driving this cursor (accumulated across
    /// `advance` calls; survives checkpoint/resume as a total).
    pub(crate) host_time_s: f64,
}

impl RunCursor {
    /// Epochs fully completed.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Next step index within the current epoch.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Global mini-batch rounds executed so far.
    pub fn rounds_done(&self) -> usize {
        self.global_step
    }

    /// Simulated seconds elapsed so far.
    pub fn sim_time_s(&self) -> f64 {
        self.sim_time_s
    }

    /// Whether the run has completed every configured epoch.
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Last evaluated test accuracy (0 until the first eval fires).
    pub fn last_accuracy(&self) -> f64 {
        self.last_accuracy
    }

    /// Host seconds spent driving this cursor so far (accumulated across
    /// `advance` calls; survives checkpoint/resume as a running total).
    /// Host-clock derived — diagnostic only, never fed back into the
    /// simulation.
    pub fn host_time_s(&self) -> f64 {
        self.host_time_s
    }

    pub(crate) fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", Json::Num(self.epoch as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("global_step", Json::Num(self.global_step as f64)),
            ("sim_time_s", Json::Str(uj::f64_to_hex(self.sim_time_s))),
            (
                "arrival_frac_sum",
                Json::Str(uj::f64_to_hex(self.arrival_frac_sum)),
            ),
            ("evals", Json::Num(self.evals as f64)),
            ("last_accuracy", Json::Str(uj::f64_to_hex(self.last_accuracy))),
            ("fault_aborts", Json::Num(self.fault_aborts as f64)),
            ("telemetry_drops", Json::Num(self.telemetry_drops as f64)),
            (
                "prev_active",
                crate::scenario::observer::ids_json(&self.prev_active),
            ),
            ("done", Json::Bool(self.done)),
            ("host_time_s", Json::Str(uj::f64_to_hex(self.host_time_s))),
        ])
    }

    pub(crate) fn from_json(j: &Json) -> Result<RunCursor> {
        Ok(RunCursor {
            epoch: j.req("epoch")?.as_usize()?,
            batch: j.req("batch")?.as_usize()?,
            global_step: j.req("global_step")?.as_usize()?,
            sim_time_s: uj::hex_to_f64(j.req("sim_time_s")?.as_str()?)?,
            arrival_frac_sum: uj::hex_to_f64(j.req("arrival_frac_sum")?.as_str()?)?,
            evals: j.req("evals")?.as_usize()?,
            last_accuracy: uj::hex_to_f64(j.req("last_accuracy")?.as_str()?)?,
            fault_aborts: j.req("fault_aborts")?.as_usize()?,
            telemetry_drops: j.req("telemetry_drops")?.as_usize()?,
            prev_active: j.req("prev_active")?.as_usize_vec()?,
            done: match j.req("done")? {
                Json::Bool(b) => *b,
                other => anyhow::bail!("cursor 'done' must be a bool, got {other:?}"),
            },
            host_time_s: uj::hex_to_f64(j.req("host_time_s")?.as_str()?)?,
        })
    }
}

/// Bit-exact matrix encoding: shape plus every f32 as a hex bit pattern.
pub(crate) fn matrix_to_json(m: &Matrix) -> Json {
    Json::obj(vec![
        ("rows", Json::Num(m.rows() as f64)),
        ("cols", Json::Num(m.cols() as f64)),
        ("data", uj::arr_f32_hex(m.data())),
    ])
}

/// Inverse of [`matrix_to_json`].
pub(crate) fn matrix_from_json(j: &Json) -> Result<Matrix> {
    let rows = j.req("rows")?.as_usize()?;
    let cols = j.req("cols")?.as_usize()?;
    let data = uj::f32_vec_from_hex(j.req("data")?)?;
    ensure!(
        data.len() == rows * cols,
        "matrix data length {} does not match shape {rows}x{cols}",
        data.len()
    );
    Ok(Matrix::from_vec(rows, cols, data))
}

/// Spec pairs as a JSON array of `[key, value]` arrays (order matters —
/// the journal replays in application order).
pub(crate) fn spec_to_json(spec: &[(String, String)]) -> Json {
    Json::Arr(
        spec.iter()
            .map(|(k, v)| Json::Arr(vec![Json::Str(k.clone()), Json::Str(v.clone())]))
            .collect(),
    )
}

/// Inverse of [`spec_to_json`].
pub(crate) fn spec_from_json(j: &Json) -> Result<Vec<(String, String)>> {
    j.as_arr()?
        .iter()
        .map(|pair| {
            let p = pair.as_arr()?;
            ensure!(p.len() == 2, "spec pair must be [key, value], got {pair:?}");
            Ok((p[0].as_str()?.to_string(), p[1].as_str()?.to_string()))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cursor_json_roundtrip_is_exact() {
        let cur = RunCursor {
            epoch: 3,
            batch: 1,
            global_step: 13,
            sim_time_s: 1234.567890123,
            arrival_frac_sum: 9.87654321,
            evals: 2,
            last_accuracy: 0.912345,
            fault_aborts: 4,
            telemetry_drops: 1,
            prev_active: vec![0, 2, 5],
            done: false,
            host_time_s: 0.25,
        };
        let j = Json::parse(&cur.to_json().to_string()).unwrap();
        let back = RunCursor::from_json(&j).unwrap();
        assert_eq!(back.epoch, cur.epoch);
        assert_eq!(back.batch, cur.batch);
        assert_eq!(back.global_step, cur.global_step);
        assert_eq!(back.sim_time_s.to_bits(), cur.sim_time_s.to_bits());
        assert_eq!(
            back.arrival_frac_sum.to_bits(),
            cur.arrival_frac_sum.to_bits()
        );
        assert_eq!(back.last_accuracy.to_bits(), cur.last_accuracy.to_bits());
        assert_eq!(back.prev_active, cur.prev_active);
        assert!(!back.done);
    }

    #[test]
    fn matrix_json_roundtrip_is_bit_exact() {
        let m = Matrix::from_vec(2, 3, vec![0.1, -0.0, 3.5e-8, f32::MIN_POSITIVE, 7.0, -2.5]);
        let j = Json::parse(&matrix_to_json(&m).to_string()).unwrap();
        let back = matrix_from_json(&j).unwrap();
        assert_eq!(back.rows(), 2);
        assert_eq!(back.cols(), 3);
        for (a, b) in back.data().iter().zip(m.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Shape mismatch is rejected.
        let bad = Json::obj(vec![
            ("rows", Json::Num(2.0)),
            ("cols", Json::Num(2.0)),
            ("data", uj::arr_f32_hex(&[1.0, 2.0, 3.0])),
        ]);
        assert!(matrix_from_json(&bad).is_err());
    }

    #[test]
    fn spec_pairs_roundtrip_in_order() {
        let spec = vec![
            ("preset".to_string(), "tiny".to_string()),
            ("seed".to_string(), "7".to_string()),
            ("scenario.churn".to_string(), "bernoulli:0.25:2".to_string()),
        ];
        let j = Json::parse(&spec_to_json(&spec).to_string()).unwrap();
        assert_eq!(spec_from_json(&j).unwrap(), spec);
    }
}
