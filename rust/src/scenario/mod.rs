//! Declarative population-scale experiments: describe an edge-FL
//! scenario, compile it, run it, stream the results.
//!
//! ```text
//! ScenarioBuilder          Scenario              Session
//! (what to run)   compile  (validated spec)  new  (runnable)
//!   population  ─────────►  cfg + dynamics ─────► trainer engine
//!   topology                                      + churn roster
//!   churn                                         + rate modulation
//!   rate processes                                + parity re-encode
//!   adaptive policy                               + control plane
//!   backend/parallelism                           │ run_observed
//!                                                 ▼
//!                                        RoundObserver events
//!                          (rounds, evals, epochs, churn, control)
//!                                                 │
//!                              ┌──────────────────┘ (adaptive only)
//!                              ▼
//!               AdaptiveController (crate::control)
//!       observer telemetry + realized delays → rate estimators
//!              → drift/cadence trigger → warm re-solve of l*_j
//!              → next epoch's RoundCtx plan + re-encoded parity
//! ```
//!
//! * [`ScenarioBuilder`] — the single construction surface for training:
//!   base preset/config, population size (with automatic `m_train`
//!   re-derivation), multi-cell [`crate::simnet::Topology`],
//!   [`crate::simnet::ChurnSchedule`], time-varying
//!   [`crate::simnet::RateProcess`]es, backend name, parallelism; plus
//!   `key = value` spec parsing (`scenario.*` keys) and named presets
//!   ([`ScenarioBuilder::named`]).
//! * [`Session`] — the compiled, runnable experiment. `run()` collects
//!   the legacy [`crate::metrics::TrainReport`]; `run_observed(&mut
//!   obs)` streams [`RoundEvent`]s/evals/epochs/churn transitions with
//!   O(1) session memory, which is how thousand-client populations
//!   report progress.
//! * [`RoundObserver`] — the streaming interface; built-ins:
//!   [`CollectingObserver`] (→ `TrainReport`), [`JsonlObserver`]
//!   (incremental JSON lines), [`ConsoleObserver`], [`EventLog`]
//!   (determinism tests), [`Fanout`].
//!
//! Static single-cell scenarios are **bitwise identical** to the legacy
//! deprecated `Trainer` constructors at any thread/shard count; dynamic
//! scenarios are bitwise reproducible from the seed (all dynamics are
//! derived on the driving thread from dedicated seed forks).

pub mod builder;
pub mod observer;
pub mod session;

pub use builder::{Scenario, ScenarioBuilder};
pub use observer::{
    ChurnEvent, CollectingObserver, ConsoleObserver, ControlEvent, EpochEvent, EventLog, Fanout,
    JsonlObserver, RoundEvent, RoundObserver,
};
pub use session::{Session, SessionSummary};
