//! Telemetry neutrality and export gates.
//!
//! * toggling telemetry recording on/off leaves the deterministic event
//!   stream and the final model **bitwise identical**, on both the flat
//!   and the hierarchical engine, at every `(threads, shards)` in
//!   {1,2}² — the observe-only rule, regression-gated;
//! * `scenario.metrics_every = N` emits canonical `"type": "metrics"`
//!   docs through `RoundObserver::on_metrics` without perturbing the
//!   event stream or the model;
//! * histogram bucket edges are fixed at registration and partition
//!   values at their first covering edge (public-API view of the
//!   snapshot shape);
//! * snapshot merge sums counters and same-axis histogram buckets,
//!   last-write-wins gauges, and replaces mismatched axes.

use std::sync::{Mutex, MutexGuard};

use anyhow::Result;
use codedfedl::config::Scheme;
use codedfedl::mathx::linalg::Matrix;
use codedfedl::mathx::par::Parallelism;
use codedfedl::metrics::EvalRecord;
use codedfedl::runtime::backend::NativeBackend;
use codedfedl::scenario::{
    ChurnEvent, ControlEvent, EpochEvent, EventLog, RoundEvent, RoundObserver, ScenarioBuilder,
};
use codedfedl::telemetry::{self, HistSnapshot, MetricsSnapshot};
use codedfedl::util::json::Json;

/// Tests that toggle the process-global enabled flag serialize on this
/// (the cargo test harness runs tests of one binary concurrently).
fn flag_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// 16-client tiny coded scenario, small enough to run the whole
/// parallelism grid twice per engine.
fn builder(hier: bool, par: Parallelism) -> ScenarioBuilder {
    let mut b = ScenarioBuilder::from_preset("tiny")
        .unwrap()
        .scheme(Scheme::Coded)
        .epochs(2)
        .population(16)
        .steps_per_epoch(2)
        .parallelism(par);
    if hier {
        b = b.hierarchical(true);
    }
    b.set("backend", "native").unwrap();
    b
}

fn run(b: ScenarioBuilder) -> (Matrix, Vec<String>) {
    let mut session = b.build_with_backend(Box::new(NativeBackend)).unwrap();
    let mut log = EventLog::new();
    session.run_observed(&mut log).unwrap();
    (session.beta().clone(), log.lines)
}

fn bits(m: &Matrix) -> Vec<u32> {
    m.data().iter().map(|f| f.to_bits()).collect()
}

#[test]
fn telemetry_toggle_is_bitwise_neutral_on_both_engines() {
    let _g = flag_lock();
    let was = telemetry::enabled();
    for hier in [false, true] {
        // Reference: telemetry off, sequential.
        telemetry::set_enabled(false);
        let (beta_off, lines_off) = run(builder(hier, Parallelism::new(1, 1)));
        // Telemetry on must reproduce it bitwise at every grid point.
        telemetry::set_enabled(true);
        for (threads, shards) in [(1, 1), (2, 1), (1, 2), (2, 2)] {
            let (beta_on, lines_on) = run(builder(hier, Parallelism::new(threads, shards)));
            let tag = format!("hier={hier} threads={threads} shards={shards}");
            assert_eq!(
                bits(&beta_on),
                bits(&beta_off),
                "{tag}: telemetry perturbed the final model"
            );
            assert_eq!(lines_on, lines_off, "{tag}: telemetry perturbed the event stream");
        }
    }
    // The gate must not be vacuous: the enabled runs actually recorded.
    let snap = telemetry::snapshot();
    assert!(
        snap.hists
            .iter()
            .any(|(name, h)| name.starts_with("phase.") && h.count > 0),
        "telemetry-on runs recorded no phase timings"
    );
    telemetry::set_enabled(was);
}

/// Forwards events to an [`EventLog`] and collects metrics docs on the
/// side, so one run yields both the deterministic stream and the
/// telemetry emissions.
#[derive(Default)]
struct MetricsTap {
    log: EventLog,
    docs: Vec<Json>,
}

impl RoundObserver for MetricsTap {
    fn on_round(&mut self, ev: &RoundEvent) -> Result<()> {
        self.log.on_round(ev)
    }
    fn on_eval(&mut self, ev: &EvalRecord) -> Result<()> {
        self.log.on_eval(ev)
    }
    fn on_epoch(&mut self, ev: &EpochEvent) -> Result<()> {
        self.log.on_epoch(ev)
    }
    fn on_churn(&mut self, ev: &ChurnEvent) -> Result<()> {
        self.log.on_churn(ev)
    }
    fn on_control(&mut self, ev: &ControlEvent) -> Result<()> {
        self.log.on_control(ev)
    }
    fn on_metrics(&mut self, doc: &Json) -> Result<()> {
        self.docs.push(doc.clone());
        Ok(())
    }
}

#[test]
fn metrics_every_emits_canonical_docs_without_perturbing_the_stream() {
    let _g = flag_lock();
    let was = telemetry::enabled();
    telemetry::set_enabled(true);
    // Reference run with the periodic emission off.
    let (beta_plain, lines_plain) = run(builder(false, Parallelism::new(1, 1)));
    // Same scenario, emitting every 2 global steps (4 steps total).
    let mut session = builder(false, Parallelism::new(1, 1))
        .metrics_every(2)
        .build_with_backend(Box::new(NativeBackend))
        .unwrap();
    let mut tap = MetricsTap::default();
    session.run_observed(&mut tap).unwrap();
    assert!(!tap.docs.is_empty(), "metrics_every=2 never emitted a metrics doc");
    for doc in &tap.docs {
        assert_eq!(doc.req("type").unwrap().as_str().unwrap(), "metrics");
        for key in ["counters", "gauges", "histograms"] {
            assert!(doc.get(key).is_some(), "metrics doc missing '{key}'");
        }
        // Round-trips through the canonical sorted-key emitter.
        assert_eq!(Json::parse(&doc.to_string()).unwrap().to_string(), doc.to_string());
    }
    assert_eq!(
        bits(session.beta()),
        bits(&beta_plain),
        "periodic metrics emission perturbed the final model"
    );
    assert_eq!(
        tap.log.lines, lines_plain,
        "metrics docs leaked into the deterministic event stream"
    );
    telemetry::set_enabled(was);
}

#[test]
fn histogram_bucket_edges_partition_at_first_covering_edge() {
    let _g = flag_lock();
    let was = telemetry::enabled();
    telemetry::set_enabled(true);
    let name = "test.it_bucket_edges";
    let h = telemetry::histogram(name, &[1.0, 2.0, 4.0]);
    for v in [0.5, 1.0, 1.5, 2.0, 3.9, 4.0, 5.0] {
        h.record(v);
    }
    // Registration fixed the axis: a later caller with different edges
    // gets the existing histogram, never a re-negotiated one.
    let again = telemetry::histogram(name, &[9.0]);
    assert_eq!(again.edges(), &[1.0, 2.0, 4.0]);
    let snap = telemetry::snapshot();
    let hs = &snap.hists[name];
    assert_eq!(hs.edges, vec![1.0, 2.0, 4.0]);
    // Bucket i counts values <= edges[i]; the last bucket is overflow.
    assert_eq!(hs.counts, vec![2, 2, 2, 1]);
    assert_eq!(hs.count, 7);
    assert!((hs.sum - 17.9).abs() < 1e-9);
    telemetry::set_enabled(was);
}

#[test]
fn snapshot_merge_adds_counts_and_replaces_mismatched_axes() {
    let mut a = MetricsSnapshot::default();
    a.counters.insert("c".into(), 3);
    a.gauges.insert("g".into(), 1.0);
    a.hists.insert(
        "h".into(),
        HistSnapshot { edges: vec![1.0, 2.0], counts: vec![1, 0, 2], count: 3, sum: 6.5 },
    );
    let mut b = MetricsSnapshot::default();
    b.counters.insert("c".into(), 4);
    b.counters.insert("d".into(), 1);
    b.gauges.insert("g".into(), 2.5);
    b.hists.insert(
        "h".into(),
        HistSnapshot { edges: vec![1.0, 2.0], counts: vec![0, 5, 1], count: 6, sum: 9.0 },
    );
    a.merge(&b);
    assert_eq!(a.counters["c"], 7);
    assert_eq!(a.counters["d"], 1);
    assert_eq!(a.gauges["g"], 2.5);
    assert_eq!(a.hists["h"].counts, vec![1, 5, 3]);
    assert_eq!(a.hists["h"].count, 9);
    assert!((a.hists["h"].sum - 15.5).abs() < 1e-12);
    // A histogram whose axis differs is replaced, never summed.
    let mut c = MetricsSnapshot::default();
    c.hists.insert(
        "h".into(),
        HistSnapshot { edges: vec![10.0], counts: vec![1, 1], count: 2, sum: 11.0 },
    );
    a.merge(&c);
    assert_eq!(a.hists["h"].edges, vec![10.0]);
    assert_eq!(a.hists["h"].count, 2);
}
