//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the training hot path.
//!
//! * [`artifact`] — `artifacts/manifest.json` parsing + shape validation.
//! * [`backend`] — the [`backend::ComputeBackend`] trait the trainer codes
//!   against, plus the pure-rust [`backend::NativeBackend`] oracle.
//! * [`xla`] — [`xla::XlaBackend`]: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `compile` → `execute`.
//!
//! Python never runs here: the artifacts are self-contained HLO.

pub mod artifact;
pub mod backend;
pub mod xla;

pub use artifact::{ArtifactMeta, Manifest, ProfileArtifacts};
pub use backend::{ComputeBackend, NativeBackend};
pub use xla::XlaBackend;
