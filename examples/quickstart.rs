//! Quickstart: train CodedFedL on the tiny synthetic dataset in seconds.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Walks the full pipeline — RFF embedding, load allocation, parity
//! encoding, coded training over the simulated MEC network — through the
//! scenario API: a [`ScenarioBuilder`] compiles the experiment into a
//! [`Session`], which streams or collects results. Falls back to the
//! native backend when artifacts have not been built yet.

use codedfedl::scenario::ScenarioBuilder;

fn main() -> anyhow::Result<()> {
    codedfedl::util::logging::init_from_env();
    // The preset's `auto` backend resolves through the registry: XLA when
    // compiled in and artifacts exist, the native pooled kernels otherwise.
    let builder = ScenarioBuilder::from_preset("tiny")?;
    let mut session = builder.build()?;
    let cfg = &session.scenario().cfg;

    println!("CodedFedL quickstart");
    println!("  dataset    : {} ({} train / {} test)", cfg.dataset, cfg.m_train, cfg.m_test);
    println!("  clients    : {} (non-IID shards)", cfg.n_clients);
    println!("  redundancy : {:.0}%", 100.0 * cfg.train.redundancy);
    println!("  backend    : {}", session.backend_name());
    if let Some(plan) = &session.setup().plan {
        println!("  deadline t*: {:.3} s, loads {:?}", plan.deadline, plan.loads);
    }

    let report = session.run()?;

    println!("\n  epoch  step  sim-time(s)  accuracy   loss");
    for r in &report.records {
        println!(
            "  {:>5}  {:>4}  {:>11.1}  {:>8.4}  {:>7.4}",
            r.epoch, r.step, r.sim_time_s, r.accuracy, r.loss
        );
    }
    println!(
        "\nfinal accuracy {:.3} after {:.1}s simulated ({:.2}s host)",
        report.final_accuracy(),
        report.total_sim_time_s,
        report.host_time_s
    );
    println!("\nnext: try a dynamic population —");
    println!("  cargo run --release --example population_scenario");
    Ok(())
}
