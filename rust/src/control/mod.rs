//! Adaptive control plane: online load re-allocation driven by streaming
//! round telemetry.
//!
//! CodedFedL's headline result is the analytical load allocation `l*_j`
//! (paper eq. 8-10) — computed once, from *known and stationary* §2.2
//! delay statistics. The scenario layer deliberately breaks both
//! assumptions: churn changes who is present every epoch, and
//! time-varying [`crate::simnet::RateProcess`]es move the compute and
//! link rates the plan was solved for. This module closes the loop:
//!
//! ```text
//! RoundObserver events + realized DelayObs      (streaming telemetry)
//!        │
//!        ▼
//! RateEstimator            windowed-MMSE / EWMA estimates of mu_j, tau_j
//!        │                 reconciled against the realized simnet delays
//!        ▼
//! ControlPolicy            off | oracle[:K] | periodic:K | drift[:θ]
//!        │                 (re-plan trigger: cadence or estimated-return
//!        ▼                  drift of the plan in force)
//! replan_fixed_u           warm-started incremental re-solve of eq. 10
//!        │                 over the *active* roster
//!        ▼
//! RoundCtx plan/mask override + parity re-encode (ReencodeCache path)
//!        │
//!        ▼
//! ControlEvent             streamed to every observer
//! ```
//!
//! * [`RateEstimator`] — per-client online estimates of the two
//!   time-varying rates, seeded from the assumed statistics.
//! * [`ControlPolicy`] — when to re-plan (the policy suite experiments
//!   compare: static baseline, ground-truth oracle, periodic, drift).
//! * [`AdaptiveController`] — the closed loop: implements
//!   [`crate::scenario::RoundObserver`], owns estimator + policy + the
//!   plan in force, and produces [`ControlDecision`]s at epoch
//!   boundaries.
//!
//! Sessions opt in through
//! [`crate::scenario::ScenarioBuilder::adaptive`] (spec key
//! `scenario.adaptive`, CLI `scenario --adaptive <policy>`). Everything
//! runs on the driving thread from deterministic telemetry, so adaptive
//! sessions are bitwise reproducible at any thread/shard count, and an
//! `off` policy is bitwise-identical to a plain session.

pub mod controller;
pub mod estimator;
pub mod policy;

pub use controller::{AdaptiveController, ControlDecision};
pub use estimator::RateEstimator;
pub use policy::ControlPolicy;
