//! Host-side runtime telemetry: a process-global metrics registry
//! (counters, gauges, fixed-bucket histograms) plus lightweight phase
//! timer [`Span`]s, wired through the hot layers (worker pool, trainers,
//! re-encode paths, session loop, serve RPCs).
//!
//! **Observe-only rule.** Telemetry reads host clocks and counts events;
//! it never feeds back into the simulation — no metric value ever
//! reaches an rng stream, a delay model, a control decision, or an event
//! the bitwise contract covers. Enabling or disabling telemetry
//! therefore leaves every event stream and the final model **bitwise
//! identical** (regression-gated in `tests/telemetry.rs` across
//! (threads, shards) on both engines), and its overhead is measured, not
//! assumed (telemetry-on vs -off round cells in `benches/kernels.rs`).
//!
//! **Determinism of the snapshot shape.** Histogram bucket edges are
//! fixed at registration from deterministic generators
//! ([`seconds_edges`], [`unit_edges`], [`count_edges`]), and snapshots
//! carry no timestamps, so two snapshots of the same run stage are
//! stably comparable: only the recorded values differ, never the schema.
//!
//! One encoder, three exports ([`MetricsSnapshot::to_json`] is the
//! single `{"type":"metrics", ...}` doc builder):
//!
//! * the `metrics` RPC on `codedfedl serve` returns a point-in-time
//!   snapshot;
//! * sessions with `scenario.metrics_every = N` emit the same doc as a
//!   periodic stream/file event through
//!   [`crate::scenario::RoundObserver::on_metrics`] (wire format ==
//!   file format);
//! * `codedfedl train`/`scenario --metrics-out <path>` dump the
//!   end-of-run snapshot to disk.
//!
//! The split against [`crate::metrics`] is intentional: `metrics` holds
//! the *paper-facing* report types (accuracy/sim-time trajectories,
//! [`crate::metrics::TrainReport`]); this module holds *host-side*
//! runtime measurements (where wall-clock goes, queue behavior,
//! realized-vs-assumed delay distributions). The knobs:
//! `CODEDFEDL_TELEMETRY=off` disables recording at startup;
//! [`set_enabled`] toggles it at runtime (the bench off-cell).

use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{OnceLock, RwLock};
use std::time::Instant;

use crate::util::json::Json;

/// Stripes per counter: bounds cross-core cache-line bouncing when pool
/// workers bump the same counter. 8 covers the pool sizes shipped here.
const STRIPES: usize = 8;

// ---- enable / disable ------------------------------------------------

fn enabled_flag() -> &'static AtomicBool {
    static FLAG: OnceLock<AtomicBool> = OnceLock::new();
    FLAG.get_or_init(|| {
        let off = std::env::var("CODEDFEDL_TELEMETRY")
            .map(|v| {
                let v = v.trim().to_ascii_lowercase();
                v == "off" || v == "0" || v == "false"
            })
            .unwrap_or(false);
        AtomicBool::new(!off)
    })
}

/// Whether recording is currently enabled (default: yes, unless
/// `CODEDFEDL_TELEMETRY=off`). Recording sites check this before taking
/// clocks or touching atomics, so a disabled process pays one relaxed
/// load per site.
pub fn enabled() -> bool {
    enabled_flag().load(Ordering::Relaxed)
}

/// Toggle recording at runtime. Observe-only either way: the setting
/// changes what is *measured*, never what is *computed*.
pub fn set_enabled(on: bool) {
    enabled_flag().store(on, Ordering::Relaxed);
}

// ---- deterministic bucket edge families ------------------------------

/// Power-of-two second edges `1e-6 * 2^i`, i = 0..=27 (1 µs … ~134 s):
/// the shared time axis for every duration histogram, so phase timings,
/// RPC latencies and delay distributions are directly comparable.
pub fn seconds_edges() -> &'static [f64] {
    static EDGES: OnceLock<Vec<f64>> = OnceLock::new();
    EDGES.get_or_init(|| (0..=27).map(|i| 1e-6 * f64::powi(2.0, i)).collect())
}

/// Linear edges over the unit interval, `i / 20` for i = 1..=20: the
/// axis for fractions (arrival fraction, occupancy).
pub fn unit_edges() -> &'static [f64] {
    static EDGES: OnceLock<Vec<f64>> = OnceLock::new();
    EDGES.get_or_init(|| (1..=20).map(|i| i as f64 / 20.0).collect())
}

/// Power-of-two count edges `2^i`, i = 0..=24: the axis for sizes and
/// margins (rows, tasks, attached workers).
pub fn count_edges() -> &'static [f64] {
    static EDGES: OnceLock<Vec<f64>> = OnceLock::new();
    EDGES.get_or_init(|| (0..=24).map(|i| f64::powi(2.0, i)).collect())
}

// ---- metric primitives -----------------------------------------------

/// A cache-line-padded atomic so counter stripes never share a line.
#[repr(align(64))]
struct PaddedU64(AtomicU64);

/// Monotone event counter, striped across [`STRIPES`] cache lines;
/// reads sum the stripes.
pub struct Counter {
    stripes: Vec<PaddedU64>,
}

fn thread_stripe() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static STRIPE: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    STRIPE.with(|c| {
        let v = c.get();
        if v != usize::MAX {
            return v;
        }
        let v = NEXT.fetch_add(1, Ordering::Relaxed) % STRIPES;
        c.set(v);
        v
    })
}

impl Counter {
    fn new() -> Counter {
        Counter { stripes: (0..STRIPES).map(|_| PaddedU64(AtomicU64::new(0))).collect() }
    }

    /// Add `n` events (no-op while telemetry is disabled).
    pub fn add(&self, n: u64) {
        if enabled() {
            self.stripes[thread_stripe()].0.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Add one event.
    pub fn incr(&self) {
        self.add(1);
    }

    /// Current total across stripes.
    pub fn value(&self) -> u64 {
        self.stripes.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }

    fn reset(&self) {
        for s in &self.stripes {
            s.0.store(0, Ordering::Relaxed);
        }
    }
}

/// Last-write-wins f64 gauge (stored as IEEE-754 bits).
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    fn new() -> Gauge {
        Gauge { bits: AtomicU64::new(0f64.to_bits()) }
    }

    /// Set the gauge (no-op while telemetry is disabled).
    pub fn set(&self, v: f64) {
        if enabled() {
            self.bits.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn value(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    fn reset(&self) {
        self.bits.store(0f64.to_bits(), Ordering::Relaxed);
    }
}

/// Fixed-bucket histogram: `edges.len() + 1` buckets where bucket `i`
/// counts values `<= edges[i]` (first matching edge) and the last bucket
/// is the overflow. Edges are fixed at registration and never change, so
/// snapshots of the same metric always share an axis.
pub struct Histogram {
    edges: Vec<f64>,
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
}

impl Histogram {
    fn new(edges: &[f64]) -> Histogram {
        assert!(
            edges.windows(2).all(|w| w[0] < w[1]),
            "histogram edges must be strictly ascending"
        );
        Histogram {
            edges: edges.to_vec(),
            counts: (0..=edges.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    /// Record one observation (no-op while telemetry is disabled).
    pub fn record(&self, v: f64) {
        if !enabled() {
            return;
        }
        let idx = self.edges.partition_point(|&e| e < v);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        // CAS fold for the f64 running sum; contention here is per-round
        // scale, not per-element.
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded values.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// The registration-time bucket edges.
    pub fn edges(&self) -> &[f64] {
        &self.edges
    }

    fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_bits.store(0f64.to_bits(), Ordering::Relaxed);
    }
}

// ---- the process-global registry -------------------------------------

enum Metric {
    C(&'static Counter),
    G(&'static Gauge),
    H(&'static Histogram),
}

fn registry() -> &'static RwLock<BTreeMap<String, Metric>> {
    static REG: OnceLock<RwLock<BTreeMap<String, Metric>>> = OnceLock::new();
    REG.get_or_init(|| RwLock::new(BTreeMap::new()))
}

/// Look up or register the named counter. Handles are `'static` (leaked
/// once per name), so hot sites may cache them. Panics if the name is
/// already registered as a different metric kind — a programming error.
pub fn counter(name: &str) -> &'static Counter {
    if let Some(m) = registry().read().unwrap().get(name) {
        match m {
            Metric::C(c) => return c,
            _ => panic!("telemetry metric '{name}' is not a counter"),
        }
    }
    let mut w = registry().write().unwrap();
    match w
        .entry(name.to_string())
        .or_insert_with(|| Metric::C(Box::leak(Box::new(Counter::new()))))
    {
        Metric::C(c) => c,
        _ => panic!("telemetry metric '{name}' is not a counter"),
    }
}

/// Look up or register the named gauge (see [`counter`] for semantics).
pub fn gauge(name: &str) -> &'static Gauge {
    if let Some(m) = registry().read().unwrap().get(name) {
        match m {
            Metric::G(g) => return g,
            _ => panic!("telemetry metric '{name}' is not a gauge"),
        }
    }
    let mut w = registry().write().unwrap();
    match w
        .entry(name.to_string())
        .or_insert_with(|| Metric::G(Box::leak(Box::new(Gauge::new()))))
    {
        Metric::G(g) => g,
        _ => panic!("telemetry metric '{name}' is not a gauge"),
    }
}

/// Look up or register the named histogram. The first registration fixes
/// the bucket edges; later calls return the existing histogram (edges
/// are never re-negotiated — determinism of the snapshot shape).
pub fn histogram(name: &str, edges: &[f64]) -> &'static Histogram {
    if let Some(m) = registry().read().unwrap().get(name) {
        match m {
            Metric::H(h) => return h,
            _ => panic!("telemetry metric '{name}' is not a histogram"),
        }
    }
    let mut w = registry().write().unwrap();
    match w
        .entry(name.to_string())
        .or_insert_with(|| Metric::H(Box::leak(Box::new(Histogram::new(edges)))))
    {
        Metric::H(h) => h,
        _ => panic!("telemetry metric '{name}' is not a histogram"),
    }
}

/// Zero every registered metric (registrations and edges stay). Used by
/// per-run isolation (`--metrics-out` dumps one run, not the process
/// history) and tests.
pub fn reset() {
    for m in registry().read().unwrap().values() {
        match m {
            Metric::C(c) => c.reset(),
            Metric::G(g) => g.reset(),
            Metric::H(h) => h.reset(),
        }
    }
}

// ---- phase-timer spans -----------------------------------------------

/// A lightweight phase timer: records elapsed host seconds into a
/// duration histogram on drop. While telemetry is disabled, constructing
/// one takes no clock and dropping it records nothing.
pub struct Span {
    live: Option<(&'static Histogram, Instant)>,
}

impl Span {
    /// A span that records nothing (the disabled arm).
    pub fn noop() -> Span {
        Span { live: None }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((h, t0)) = self.live.take() {
            h.record(t0.elapsed().as_secs_f64());
        }
    }
}

/// Start a phase timer recording into histogram `name` (registered on
/// the shared [`seconds_edges`] axis).
pub fn span(name: &str) -> Span {
    if !enabled() {
        return Span::noop();
    }
    Span { live: Some((histogram(name, seconds_edges()), Instant::now())) }
}

// ---- snapshots -------------------------------------------------------

/// Point-in-time state of one histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSnapshot {
    pub edges: Vec<f64>,
    /// Per-bucket counts (`edges.len() + 1`, last is overflow).
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

/// Point-in-time state of the whole registry: the one value every
/// export path shares.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub hists: BTreeMap<String, HistSnapshot>,
}

/// Capture the registry.
pub fn snapshot() -> MetricsSnapshot {
    let mut s = MetricsSnapshot::default();
    for (name, m) in registry().read().unwrap().iter() {
        match m {
            Metric::C(c) => {
                s.counters.insert(name.clone(), c.value());
            }
            Metric::G(g) => {
                s.gauges.insert(name.clone(), g.value());
            }
            Metric::H(h) => {
                s.hists.insert(
                    name.clone(),
                    HistSnapshot {
                        edges: h.edges.clone(),
                        counts: h.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
                        count: h.count(),
                        sum: h.sum(),
                    },
                );
            }
        }
    }
    s
}

impl MetricsSnapshot {
    /// Fold `other` into `self`: counters and histogram buckets add
    /// (same-edge histograms only — a histogram whose edges differ is
    /// replaced by `other`'s, since summing across axes is meaningless);
    /// gauges are last-write-wins (`other` wins).
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.hists {
            match self.hists.get_mut(k) {
                Some(mine) if mine.edges == h.edges => {
                    for (a, b) in mine.counts.iter_mut().zip(&h.counts) {
                        *a += b;
                    }
                    mine.count += h.count;
                    mine.sum += h.sum;
                }
                _ => {
                    self.hists.insert(k.clone(), h.clone());
                }
            }
        }
    }

    /// The canonical `{"type":"metrics", ...}` document — the single
    /// encoder behind the serve `metrics` RPC, the periodic stream/file
    /// metrics event, and the `--metrics-out` dump (wire format == file
    /// format). No timestamps: snapshots of the same stage are stably
    /// comparable.
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters.iter().map(|(k, &v)| (k.clone(), Json::Num(v as f64))).collect(),
        );
        let gauges =
            Json::Obj(self.gauges.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect());
        let hists = Json::Obj(
            self.hists
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("edges", Json::arr_f64(&h.edges)),
                            (
                                "counts",
                                Json::Arr(h.counts.iter().map(|&c| Json::Num(c as f64)).collect()),
                            ),
                            ("count", Json::Num(h.count as f64)),
                            ("sum", Json::Num(h.sum)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("type", Json::Str("metrics".into())),
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", hists),
        ])
    }

    /// The `k` phase histograms (`phase.*`) with the largest cumulative
    /// host seconds, as `(phase name, total seconds)` descending — the
    /// done-line / status-doc host-time breakdown.
    pub fn top_phases(&self, k: usize) -> Vec<(String, f64)> {
        let mut v: Vec<(String, f64)> = self
            .hists
            .iter()
            .filter(|(name, _)| name.starts_with("phase."))
            .map(|(name, h)| (name["phase.".len()..].to_string(), h.sum))
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
        v.truncate(k);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// Tests that toggle the global enabled flag serialize on this.
    fn flag_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn edge_families_are_deterministic_and_ascending() {
        for edges in [seconds_edges(), unit_edges(), count_edges()] {
            assert!(edges.windows(2).all(|w| w[0] < w[1]));
        }
        assert_eq!(seconds_edges()[0], 1e-6);
        assert_eq!(seconds_edges().len(), 28);
        assert_eq!(seconds_edges()[27], 1e-6 * f64::powi(2.0, 27));
        assert_eq!(unit_edges().first(), Some(&0.05));
        assert_eq!(unit_edges().last(), Some(&1.0));
        assert_eq!(count_edges()[0], 1.0);
        assert_eq!(count_edges()[24], (1u64 << 24) as f64);
        // Two calls return the same (cached) axis.
        assert_eq!(seconds_edges().as_ptr(), seconds_edges().as_ptr());
    }

    #[test]
    fn histogram_buckets_values_at_their_first_covering_edge() {
        let _g = flag_lock();
        set_enabled(true);
        let h = Histogram::new(&[1.0, 2.0, 4.0]);
        for (v, want) in [(0.5, 0), (1.0, 0), (1.5, 1), (2.0, 1), (3.9, 2), (4.0, 2), (5.0, 3)] {
            let before = h.counts[want].load(Ordering::Relaxed);
            h.record(v);
            assert_eq!(h.counts[want].load(Ordering::Relaxed), before + 1, "value {v}");
        }
        assert_eq!(h.count(), 7);
        assert!((h.sum() - (0.5 + 1.0 + 1.5 + 2.0 + 3.9 + 4.0 + 5.0)).abs() < 1e-12);
    }

    #[test]
    fn counters_sum_across_stripes_and_threads() {
        let _g = flag_lock();
        set_enabled(true);
        let c = counter("test.stripes");
        let before = c.value();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        c.incr();
                    }
                });
            }
        });
        assert_eq!(c.value() - before, 4000);
    }

    #[test]
    fn disabled_recording_is_a_no_op() {
        let _g = flag_lock();
        set_enabled(false);
        let c = counter("test.disabled");
        let h = histogram("test.disabled_h", seconds_edges());
        let g = gauge("test.disabled_g");
        let (c0, h0, g0) = (c.value(), h.count(), g.value());
        c.add(5);
        h.record(1.0);
        g.set(9.0);
        drop(span("test.disabled_h"));
        assert_eq!(c.value(), c0);
        assert_eq!(h.count(), h0);
        assert_eq!(g.value(), g0);
        set_enabled(true);
    }

    #[test]
    fn span_records_elapsed_seconds() {
        let _g = flag_lock();
        set_enabled(true);
        let h = histogram("phase.test_span", seconds_edges());
        let before = h.count();
        drop(span("phase.test_span"));
        assert_eq!(h.count(), before + 1);
        assert!(h.sum() >= 0.0);
    }

    #[test]
    fn snapshot_merge_sums_counts_and_keeps_latest_gauge() {
        let mut a = MetricsSnapshot::default();
        a.counters.insert("c".into(), 3);
        a.gauges.insert("g".into(), 1.0);
        a.hists.insert(
            "h".into(),
            HistSnapshot { edges: vec![1.0, 2.0], counts: vec![1, 0, 2], count: 3, sum: 6.5 },
        );
        let mut b = MetricsSnapshot::default();
        b.counters.insert("c".into(), 4);
        b.counters.insert("d".into(), 1);
        b.gauges.insert("g".into(), 2.5);
        b.hists.insert(
            "h".into(),
            HistSnapshot { edges: vec![1.0, 2.0], counts: vec![0, 5, 1], count: 6, sum: 9.0 },
        );
        a.merge(&b);
        assert_eq!(a.counters["c"], 7);
        assert_eq!(a.counters["d"], 1);
        assert_eq!(a.gauges["g"], 2.5);
        assert_eq!(a.hists["h"].counts, vec![1, 5, 3]);
        assert_eq!(a.hists["h"].count, 9);
        assert!((a.hists["h"].sum - 15.5).abs() < 1e-12);
        // Mismatched axes are replaced, never summed.
        let mut c = MetricsSnapshot::default();
        c.hists.insert(
            "h".into(),
            HistSnapshot { edges: vec![10.0], counts: vec![1, 1], count: 2, sum: 11.0 },
        );
        a.merge(&c);
        assert_eq!(a.hists["h"].edges, vec![10.0]);
        assert_eq!(a.hists["h"].count, 2);
    }

    #[test]
    fn snapshot_doc_is_the_canonical_metrics_event() {
        let _g = flag_lock();
        set_enabled(true);
        counter("test.doc").incr();
        gauge("test.doc_g").set(0.5);
        histogram("test.doc_h", &[1.0]).record(0.25);
        let doc = snapshot().to_json();
        assert_eq!(doc.get("type").unwrap().as_str().unwrap(), "metrics");
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        assert!(back.get("counters").unwrap().get("test.doc").is_some());
        assert!(back.get("gauges").unwrap().get("test.doc_g").is_some());
        let h = back.get("histograms").unwrap().get("test.doc_h").unwrap();
        assert_eq!(h.req("edges").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(h.req("counts").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn top_phases_ranks_by_cumulative_seconds() {
        let mut s = MetricsSnapshot::default();
        for (name, sum) in [("phase.a", 1.0), ("phase.b", 5.0), ("phase.c", 3.0)] {
            s.hists.insert(
                name.into(),
                HistSnapshot { edges: vec![1.0], counts: vec![1, 0], count: 1, sum },
            );
        }
        s.hists.insert(
            "other.h".into(),
            HistSnapshot { edges: vec![1.0], counts: vec![1, 0], count: 1, sum: 99.0 },
        );
        let top = s.top_phases(2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].0, "b");
        assert_eq!(top[1].0, "c");
    }
}
